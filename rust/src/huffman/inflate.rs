//! Inflating: per-chunk canonical Huffman decoding (paper §3.3).
//!
//! Within a chunk, decoding is inherently sequential (variable-length
//! codes are a loop-carried dependency, as the paper notes); across
//! chunks it parallelizes coarsely. Inflate must use the chunk geometry
//! chosen at deflate time (Table 6's constraint).

use super::{DeflatedStream, ReverseCodebook};
use crate::util::bitio::BitReader;
use crate::util::pool::parallel_map;

/// Decode an entire stream back to symbols.
///
/// Chunks decode directly into disjoint slices of one output buffer (no
/// per-chunk vectors, no concatenation copy) — chunk geometry is fixed at
/// deflate time, so slice boundaries are known up front.
pub fn inflate_chunks(stream: &DeflatedStream, rev: &ReverseCodebook, threads: usize) -> Vec<u16> {
    let total = stream.total_symbols() as usize;
    let cs = stream.chunk_symbols.max(1);
    let mut out = vec![0u16; total];
    // geometry check: every chunk but the last must hold exactly cs symbols
    let regular = stream
        .chunks
        .iter()
        .take(stream.chunks.len().saturating_sub(1))
        .all(|c| c.symbols as usize == cs);
    if !regular {
        // irregular (hand-built) stream: fall back to sequential decode
        let mut pos = 0usize;
        for chunk in &stream.chunks {
            let n = decode_chunk_into(chunk, rev, &mut out[pos..]);
            pos += n;
        }
        out.truncate(pos);
        return out;
    }
    let tasks: Vec<(usize, std::sync::Mutex<&mut [u16]>)> = out
        .chunks_mut(cs)
        .enumerate()
        .map(|(i, s)| (i, std::sync::Mutex::new(s)))
        .collect();
    let counts = parallel_map(threads, &tasks, |_, (i, slot)| {
        let mut slice = slot.lock().unwrap();
        decode_chunk_into(&stream.chunks[*i], rev, &mut slice)
    });
    drop(tasks);
    let produced: usize = counts.iter().sum();
    if produced != total {
        // a corrupt chunk under-produced mid-buffer: redo sequentially,
        // compacting, so strict callers see the true (short) symbol count
        let mut seq = vec![0u16; total];
        let mut pos = 0usize;
        for chunk in &stream.chunks {
            pos += decode_chunk_into(chunk, rev, &mut seq[pos..]);
        }
        seq.truncate(pos);
        return seq;
    }
    out
}

/// Decode one chunk into `out`, returning symbols produced.
fn decode_chunk_into(
    chunk: &super::deflate::DeflatedChunk,
    rev: &ReverseCodebook,
    out: &mut [u16],
) -> usize {
    let want = (chunk.symbols as usize).min(out.len());
    let mut r = BitReader::new(&chunk.words, chunk.bits);
    for (i, slot) in out[..want].iter_mut().enumerate() {
        match rev.decode(&mut r) {
            Some(s) => *slot = s,
            None => return i,
        }
    }
    want
}

/// Decode exactly one chunk into a caller-provided window, erroring
/// (never panicking) when the chunk under-produces or its claimed symbol
/// count disagrees with the window — the per-chunk entry point of the
/// zero-copy decompress path (`SymbolSink` windows) and of
/// mixed-granularity archives, where only some chunks are Huffman-tagged.
pub fn inflate_one_into_strict(
    chunk: &super::deflate::DeflatedChunk,
    rev: &ReverseCodebook,
    out: &mut [u16],
) -> anyhow::Result<()> {
    if chunk.symbols as usize != out.len() {
        anyhow::bail!(
            "corrupt huffman chunk: claims {} symbols for a {}-symbol window",
            chunk.symbols,
            out.len()
        );
    }
    let got = decode_chunk_into(chunk, rev, out);
    if got != out.len() {
        anyhow::bail!(
            "corrupt huffman chunk: produced {got} of {} symbols",
            out.len()
        );
    }
    Ok(())
}

/// Materializing wrapper over [`inflate_one_into_strict`]. The caller
/// must bound `chunk.symbols` (it is untrusted) before this allocates.
pub fn inflate_one_strict(
    chunk: &super::deflate::DeflatedChunk,
    rev: &ReverseCodebook,
) -> anyhow::Result<Vec<u16>> {
    let mut out = vec![0u16; chunk.symbols as usize];
    inflate_one_into_strict(chunk, rev, &mut out)?;
    Ok(out)
}

/// Strict variant: errors on corrupt chunks instead of truncating.
pub fn inflate_chunks_strict(
    stream: &DeflatedStream,
    rev: &ReverseCodebook,
    threads: usize,
) -> anyhow::Result<Vec<u16>> {
    let out = inflate_chunks(stream, rev, threads);
    let expect = stream.total_symbols();
    if out.len() as u64 != expect {
        anyhow::bail!("inflate produced {} symbols, expected {expect}", out.len());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::codebook::CanonicalCodebook;
    use crate::huffman::deflate::deflate_chunks;
    use crate::huffman::tree::build_lengths;
    use crate::util::prng::Rng;

    #[test]
    fn inflate_inverts_deflate_across_chunk_sizes() {
        let mut rng = Rng::new(33);
        let syms: Vec<u16> = (0..40_000)
            .map(|_| ((rng.normal() * 20.0) as i32 + 512).clamp(0, 1023) as u16)
            .collect();
        let mut freq = vec![0u64; 1024];
        for &s in &syms {
            freq[s as usize] += 1;
        }
        let lengths = build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        for chunk in [64usize, 500, 4096, 65536] {
            let stream = deflate_chunks(&syms, &book, chunk, 4);
            let out = inflate_chunks_strict(&stream, &rev, 4).unwrap();
            assert_eq!(out, syms, "chunk {chunk}");
        }
    }

    #[test]
    fn corrupt_stream_is_detected() {
        let syms = vec![1u16; 1000];
        let mut freq = vec![0u64; 4];
        freq[1] = 1000;
        freq[2] = 1; // ensure 2 symbols so codes exist
        let lengths = build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        let mut stream = deflate_chunks(&syms, &book, 100, 1);
        // truncate a chunk's bitstream
        stream.chunks[3].bits = stream.chunks[3].bits.saturating_sub(40);
        assert!(inflate_chunks_strict(&stream, &rev, 2).is_err());
    }
}
