//! Inflating: per-chunk canonical Huffman decoding (paper §3.3).
//!
//! Within a chunk, plain decoding is inherently sequential (variable-
//! length codes are a loop-carried dependency, as the paper notes);
//! across chunks it parallelizes coarsely. Inflate must use the chunk
//! geometry chosen at deflate time (Table 6's constraint). When the
//! archive carries a gap table ([`super::deflate::deflate_one_gap`]),
//! [`inflate_one_gap_into_strict`] breaks the intra-chunk dependency too:
//! subchunks resume at recorded bit offsets and decode in parallel.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::deflate::DeflatedChunk;
use super::{DeflatedStream, ReverseCodebook};
use crate::codec::SymbolSink;
use crate::util::bitio::BitReader;

/// Decode an entire stream back to symbols.
///
/// Chunks decode directly into the disjoint prefix-sum windows of one
/// output buffer — the same unsafe-free split [`SymbolSink`] hands every
/// decoder backend — so there are no per-chunk `Mutex` slots, no per-chunk
/// vectors, and no concatenation copy. The partition follows the chunks'
/// own symbol counts, so irregular (hand-built) geometries need no
/// sequential fallback either.
pub fn inflate_chunks(stream: &DeflatedStream, rev: &ReverseCodebook, threads: usize) -> Vec<u16> {
    let total = stream.total_symbols() as usize;
    let mut out = vec![0u16; total];
    let counts: Vec<AtomicUsize> = stream.chunks.iter().map(|_| AtomicUsize::new(0)).collect();
    SymbolSink::from_slice(&mut out)
        .fill_chunks(stream, threads, |ci, window| {
            let n = decode_chunk_into(&stream.chunks[ci], rev, window);
            counts[ci].store(n, Ordering::Relaxed);
            Ok(())
        })
        .expect("a buffer sized to the stream total always partitions");
    let produced: usize = counts.iter().map(|c| c.load(Ordering::Relaxed)).sum();
    if produced != total {
        // One or more corrupt chunks under-produced mid-buffer. Reuse the
        // already-decoded prefixes: compact each chunk's produced symbols
        // forward in place instead of re-decoding the whole stream.
        let mut write = 0usize;
        let mut read = 0usize;
        for (ci, chunk) in stream.chunks.iter().enumerate() {
            let n = counts[ci].load(Ordering::Relaxed);
            out.copy_within(read..read + n, write);
            write += n;
            read += chunk.symbols as usize;
        }
        out.truncate(write);
    }
    out
}

/// Decode one chunk into `out`, returning symbols produced.
fn decode_chunk_into(
    chunk: &super::deflate::DeflatedChunk,
    rev: &ReverseCodebook,
    out: &mut [u16],
) -> usize {
    let want = (chunk.symbols as usize).min(out.len());
    let mut r = BitReader::new(&chunk.words, chunk.bits);
    for (i, slot) in out[..want].iter_mut().enumerate() {
        match rev.decode(&mut r) {
            Some(s) => *slot = s,
            None => return i,
        }
    }
    want
}

/// Decode exactly one chunk into a caller-provided window, erroring
/// (never panicking) when the chunk under-produces or its claimed symbol
/// count disagrees with the window — the per-chunk entry point of the
/// zero-copy decompress path (`SymbolSink` windows) and of
/// mixed-granularity archives, where only some chunks are Huffman-tagged.
pub fn inflate_one_into_strict(
    chunk: &super::deflate::DeflatedChunk,
    rev: &ReverseCodebook,
    out: &mut [u16],
) -> anyhow::Result<()> {
    if chunk.symbols as usize != out.len() {
        anyhow::bail!(
            "corrupt huffman chunk: claims {} symbols for a {}-symbol window",
            chunk.symbols,
            out.len()
        );
    }
    let got = decode_chunk_into(chunk, rev, out);
    if got != out.len() {
        anyhow::bail!(
            "corrupt huffman chunk: produced {got} of {} symbols",
            out.len()
        );
    }
    Ok(())
}

/// Gap-array decode of one chunk (arXiv 2201.09118): the recorded
/// per-subchunk `(bit_offset, symbol_count)` table turns the chunk's
/// "inherently sequential" bit walk into independent subchunk decodes that
/// fan across `threads` workers — the path that lets a *single large
/// chunk* saturate all cores.
///
/// The gap table is untrusted archive input. It is validated against the
/// chunk's own `bits`/`symbols` totals before any subchunk decodes
/// (offsets strictly increasing from 0, in range, counts positive and
/// summing exactly), and every subchunk decode must land exactly on the
/// next recorded offset — so a hostile table that disagrees with the real
/// bitstream fails cleanly, and a table that passes is *proof* the result
/// is bit-identical to the serial walk. An absent/trivial table (or a
/// single-thread budget) falls back to [`inflate_one_into_strict`].
pub fn inflate_one_gap_into_strict(
    chunk: &DeflatedChunk,
    gaps: &[(u64, u32)],
    rev: &ReverseCodebook,
    out: &mut [u16],
    threads: usize,
) -> anyhow::Result<()> {
    if gaps.len() <= 1 || threads <= 1 {
        return inflate_one_into_strict(chunk, rev, out);
    }
    if chunk.symbols as usize != out.len() {
        anyhow::bail!(
            "corrupt huffman chunk: claims {} symbols for a {}-symbol window",
            chunk.symbols,
            out.len()
        );
    }
    if chunk.bits > chunk.words.len() as u64 * 64 {
        anyhow::bail!(
            "corrupt huffman chunk: {} bits in {} words",
            chunk.bits,
            chunk.words.len()
        );
    }
    let mut total = 0u64;
    for (si, &(off, count)) in gaps.iter().enumerate() {
        if count == 0 {
            anyhow::bail!("corrupt gap table: subchunk {si} claims zero symbols");
        }
        if si == 0 && off != 0 {
            anyhow::bail!("corrupt gap table: first subchunk starts at bit {off}");
        }
        if si > 0 && off <= gaps[si - 1].0 {
            anyhow::bail!("corrupt gap table: offsets not strictly increasing at subchunk {si}");
        }
        if off >= chunk.bits {
            anyhow::bail!(
                "corrupt gap table: subchunk {si} starts at bit {off} of {}",
                chunk.bits
            );
        }
        total += count as u64;
    }
    if total != chunk.symbols as u64 {
        anyhow::bail!(
            "corrupt gap table: subchunks claim {total} symbols, chunk claims {}",
            chunk.symbols
        );
    }
    // Reuse the sink's prefix-sum partition to hand each subchunk its
    // disjoint window of `out`; a counts-only stream drives the split.
    let sub_stream = DeflatedStream {
        chunks: gaps
            .iter()
            .map(|&(_, symbols)| DeflatedChunk { words: Vec::new(), bits: 0, symbols })
            .collect(),
        chunk_symbols: gaps[0].1 as usize,
    };
    SymbolSink::from_slice(out).fill_chunks(&sub_stream, threads, |si, window| {
        let end = if si + 1 < gaps.len() { gaps[si + 1].0 } else { chunk.bits };
        let mut r = BitReader::new_at(&chunk.words, chunk.bits, gaps[si].0);
        for slot in window.iter_mut() {
            match rev.decode(&mut r) {
                Some(s) => *slot = s,
                None => anyhow::bail!("corrupt huffman subchunk {si}: truncated mid-stream"),
            }
        }
        if r.position() != end {
            anyhow::bail!(
                "corrupt gap table: subchunk {si} ends at bit {} instead of {end}",
                r.position()
            );
        }
        Ok(())
    })
}

/// Materializing wrapper over [`inflate_one_into_strict`]. The caller
/// must bound `chunk.symbols` (it is untrusted) before this allocates.
pub fn inflate_one_strict(
    chunk: &super::deflate::DeflatedChunk,
    rev: &ReverseCodebook,
) -> anyhow::Result<Vec<u16>> {
    let mut out = vec![0u16; chunk.symbols as usize];
    inflate_one_into_strict(chunk, rev, &mut out)?;
    Ok(out)
}

/// Strict variant: errors on corrupt chunks instead of truncating.
pub fn inflate_chunks_strict(
    stream: &DeflatedStream,
    rev: &ReverseCodebook,
    threads: usize,
) -> anyhow::Result<Vec<u16>> {
    let out = inflate_chunks(stream, rev, threads);
    let expect = stream.total_symbols();
    if out.len() as u64 != expect {
        anyhow::bail!("inflate produced {} symbols, expected {expect}", out.len());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::codebook::CanonicalCodebook;
    use crate::huffman::deflate::deflate_chunks;
    use crate::huffman::tree::build_lengths;
    use crate::util::prng::Rng;

    #[test]
    fn inflate_inverts_deflate_across_chunk_sizes() {
        let mut rng = Rng::new(33);
        let syms: Vec<u16> = (0..40_000)
            .map(|_| ((rng.normal() * 20.0) as i32 + 512).clamp(0, 1023) as u16)
            .collect();
        let mut freq = vec![0u64; 1024];
        for &s in &syms {
            freq[s as usize] += 1;
        }
        let lengths = build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        for chunk in [64usize, 500, 4096, 65536] {
            let stream = deflate_chunks(&syms, &book, chunk, 4);
            let out = inflate_chunks_strict(&stream, &rev, 4).unwrap();
            assert_eq!(out, syms, "chunk {chunk}");
        }
    }

    fn gap_setup(n: usize) -> (Vec<u16>, CanonicalCodebook, ReverseCodebook) {
        let mut rng = Rng::new(44);
        let syms: Vec<u16> = (0..n)
            .map(|_| ((rng.normal() * 25.0) as i32 + 512).clamp(0, 1023) as u16)
            .collect();
        let mut freq = vec![0u64; 1024];
        for &s in &syms {
            freq[s as usize] += 1;
        }
        let lengths = build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        (syms, book, rev)
    }

    #[test]
    fn gap_decode_is_bit_identical_to_serial() {
        use crate::huffman::deflate::{deflate_one_gap, GAP_SUBCHUNK};
        for n in [GAP_SUBCHUNK + 1, GAP_SUBCHUNK * 4, GAP_SUBCHUNK * 7 + 123] {
            let (syms, book, rev) = gap_setup(n);
            let (chunk, gaps) = deflate_one_gap(&syms, &book);
            assert!(gaps.len() > 1);
            let mut serial = vec![0u16; n];
            inflate_one_into_strict(&chunk, &rev, &mut serial).unwrap();
            for threads in [1usize, 2, 4, 8] {
                let mut gap = vec![0u16; n];
                inflate_one_gap_into_strict(&chunk, &gaps, &rev, &mut gap, threads).unwrap();
                assert_eq!(gap, serial, "n={n} threads={threads}");
                assert_eq!(gap, syms);
            }
        }
    }

    #[test]
    fn hostile_gap_tables_fail_cleanly() {
        use crate::huffman::deflate::{deflate_one_gap, GAP_SUBCHUNK};
        let (syms, book, rev) = gap_setup(GAP_SUBCHUNK * 3 + 50);
        let (chunk, gaps) = deflate_one_gap(&syms, &book);
        let mut out = vec![0u16; syms.len()];
        let check = |gaps: &[(u64, u32)]| {
            inflate_one_gap_into_strict(&chunk, gaps, &rev, &mut vec![0u16; syms.len()], 4)
        };
        // the honest table decodes
        inflate_one_gap_into_strict(&chunk, &gaps, &rev, &mut out, 4).unwrap();

        // offsets out of order
        let mut bad = gaps.clone();
        bad.swap(1, 2);
        assert!(check(&bad).is_err());
        // offset past chunk.bits
        let mut bad = gaps.clone();
        bad[2].0 = chunk.bits + 7;
        assert!(check(&bad).is_err());
        // first offset nonzero
        let mut bad = gaps.clone();
        bad[0].0 = 3;
        assert!(check(&bad).is_err());
        // offset nudged off a codeword boundary: end-position check trips
        let mut bad = gaps.clone();
        bad[1].0 += 1;
        assert!(check(&bad).is_err());
        // symbol counts inflated (sum mismatch)
        let mut bad = gaps.clone();
        bad[1].1 += 10;
        assert!(check(&bad).is_err());
        // counts shuffled to keep the sum but break subchunk windows
        let mut bad = gaps.clone();
        bad[1].1 += 10;
        bad[2].1 -= 10;
        assert!(check(&bad).is_err());
        // zero-count subchunk
        let mut bad = gaps.clone();
        bad[2].1 = 0;
        assert!(check(&bad).is_err());
        // serial fallback ignores an empty table
        inflate_one_gap_into_strict(&chunk, &[], &rev, &mut out, 4).unwrap();
        assert_eq!(out, syms);
    }

    #[test]
    fn corrupt_chunks_keep_already_decoded_prefixes() {
        let (syms, book, rev) = gap_setup(4000);
        let mut stream = deflate_chunks(&syms, &book, 500, 2);
        // truncate chunk 5's bitstream: its decode under-produces
        stream.chunks[5].bits = stream.chunks[5].bits.saturating_sub(40);
        let out = inflate_chunks(&stream, &rev, 4);
        assert!(out.len() < syms.len());
        // chunks 0..5 decoded in place and survived compaction verbatim
        assert_eq!(&out[..2500], &syms[..2500]);
        // whatever chunk 5 produced is a prefix of its original symbols
        let tail_produced = out.len() - 2500 - 1000; // chunks 6,7 (500 each) follow
        assert_eq!(&out[2500..2500 + tail_produced], &syms[2500..2500 + tail_produced]);
        // chunks 6 and 7 decoded fully and were compacted forward
        assert_eq!(&out[2500 + tail_produced..], &syms[3000..]);
    }

    #[test]
    fn corrupt_stream_is_detected() {
        let syms = vec![1u16; 1000];
        let mut freq = vec![0u64; 4];
        freq[1] = 1000;
        freq[2] = 1; // ensure 2 symbols so codes exist
        let lengths = build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        let mut stream = deflate_chunks(&syms, &book, 100, 1);
        // truncate a chunk's bitstream
        stream.chunks[3].bits = stream.chunks[3].bits.saturating_sub(40);
        assert!(inflate_chunks_strict(&stream, &rev, 2).is_err());
    }
}
