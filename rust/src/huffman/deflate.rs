//! Deflating: concatenate variable-length codewords into dense per-chunk
//! bitstreams (paper §3.2.4). Chunks are independent so both deflate and
//! inflate parallelize coarsely (chunk ↔ worker), and the chunk size is the
//! tuning knob Table 6 sweeps.

use super::CanonicalCodebook;
use crate::util::bitio::BitWriter;
use crate::util::pool::parallel_map_range;

/// Gap-array subchunk granularity (arXiv 2201.09118): every
/// `GAP_SUBCHUNK` symbols the deflater records the bit offset where the
/// next subchunk starts, so inflate can fan subchunks of one chunk across
/// threads instead of walking the whole chunk serially.
pub const GAP_SUBCHUNK: usize = 4096;

/// Per-subchunk gap table for one chunk: `(bit_offset, symbol_count)` per
/// subchunk, in stream order. Empty when the chunk fits one subchunk (the
/// serial decode is already optimal there).
pub type GapTable = Vec<(u64, u32)>;

/// One deflated chunk: packed words + exact bit length + symbol count.
#[derive(Debug, Clone, PartialEq)]
pub struct DeflatedChunk {
    pub words: Vec<u64>,
    pub bits: u64,
    pub symbols: u32,
}

/// A deflated symbol stream (per-field unit of the archive).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeflatedStream {
    pub chunks: Vec<DeflatedChunk>,
    pub chunk_symbols: usize,
}

impl DeflatedStream {
    pub fn total_bits(&self) -> u64 {
        self.chunks.iter().map(|c| c.bits).sum()
    }

    pub fn total_symbols(&self) -> u64 {
        self.chunks.iter().map(|c| c.symbols as u64).sum()
    }

    /// Compressed payload size in bytes (word-padded per chunk).
    pub fn payload_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.words.len() * 8).sum()
    }
}

/// Fused lookup+deflate over fixed-size symbol chunks, in parallel.
pub fn deflate_chunks(
    symbols: &[u16],
    book: &CanonicalCodebook,
    chunk_symbols: usize,
    threads: usize,
) -> DeflatedStream {
    let chunk_symbols = chunk_symbols.max(1);
    let nchunks = symbols.len().div_ceil(chunk_symbols);
    let chunks = parallel_map_range(threads, nchunks, |ci| {
        let lo = ci * chunk_symbols;
        let hi = (lo + chunk_symbols).min(symbols.len());
        deflate_one(&symbols[lo..hi], book)
    });
    DeflatedStream { chunks, chunk_symbols }
}

/// Deflate one chunk (hot loop: one table load + one bit append per symbol).
pub fn deflate_one(symbols: &[u16], book: &CanonicalCodebook) -> DeflatedChunk {
    // Pre-size: worst case max_len bits per symbol.
    let mut w =
        BitWriter::with_capacity_bits(symbols.len() * book.max_len.max(1) as usize);
    for &s in symbols {
        let (c, l) = book.lookup(s);
        w.write(c, l);
    }
    let (words, bits) = w.finish();
    DeflatedChunk { words, bits, symbols: symbols.len() as u32 }
}

/// [`deflate_one`] plus a recorded gap table: the writer's bit position is
/// sampled at every `GAP_SUBCHUNK` boundary. The emitted chunk is
/// bit-identical to `deflate_one`'s — the table is pure metadata on the
/// side — so archives with and without gap tables carry the same payload.
pub fn deflate_one_gap(symbols: &[u16], book: &CanonicalCodebook) -> (DeflatedChunk, GapTable) {
    if symbols.len() <= GAP_SUBCHUNK {
        return (deflate_one(symbols, book), GapTable::new());
    }
    let mut w =
        BitWriter::with_capacity_bits(symbols.len() * book.max_len.max(1) as usize);
    let mut gaps = GapTable::with_capacity(symbols.len().div_ceil(GAP_SUBCHUNK));
    for sub in symbols.chunks(GAP_SUBCHUNK) {
        gaps.push((w.len_bits(), sub.len() as u32));
        for &s in sub {
            let (c, l) = book.lookup(s);
            w.write(c, l);
        }
    }
    let (words, bits) = w.finish();
    (DeflatedChunk { words, bits, symbols: symbols.len() as u32 }, gaps)
}

/// Deflate a pre-encoded fixed-length u32 array (Table 4's second phase:
/// reads the packed repr instead of the codebook).
pub fn deflate_fixed_u32(encoded: &[u32], chunk_symbols: usize, threads: usize) -> DeflatedStream {
    let chunk_symbols = chunk_symbols.max(1);
    let nchunks = encoded.len().div_ceil(chunk_symbols);
    let chunks = parallel_map_range(threads, nchunks, |ci| {
        let lo = ci * chunk_symbols;
        let hi = (lo + chunk_symbols).min(encoded.len());
        let mut w = BitWriter::with_capacity_bits((hi - lo) * 24);
        for &e in &encoded[lo..hi] {
            w.write((e & 0x00ff_ffff) as u64, e >> 24);
        }
        let (words, bits) = w.finish();
        DeflatedChunk { words, bits, symbols: (hi - lo) as u32 }
    });
    DeflatedStream { chunks, chunk_symbols }
}

/// Deflate a pre-encoded fixed-length u64 array.
pub fn deflate_fixed_u64(encoded: &[u64], chunk_symbols: usize, threads: usize) -> DeflatedStream {
    let chunk_symbols = chunk_symbols.max(1);
    let nchunks = encoded.len().div_ceil(chunk_symbols);
    let chunks = parallel_map_range(threads, nchunks, |ci| {
        let lo = ci * chunk_symbols;
        let hi = (lo + chunk_symbols).min(encoded.len());
        let mut w = BitWriter::with_capacity_bits((hi - lo) * 32);
        for &e in &encoded[lo..hi] {
            w.write(e & ((1u64 << 56) - 1), (e >> 56) as u32);
        }
        let (words, bits) = w.finish();
        DeflatedChunk { words, bits, symbols: (hi - lo) as u32 }
    });
    DeflatedStream { chunks, chunk_symbols }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::encode::{encode_fixed_u32, encode_fixed_u64, encoded_bits};
    use crate::huffman::tree::build_lengths;
    use crate::util::prng::Rng;

    fn setup(n: usize) -> (Vec<u16>, CanonicalCodebook) {
        let mut rng = Rng::new(21);
        let syms: Vec<u16> = (0..n)
            .map(|_| ((rng.normal() * 5.0) as i32 + 512).clamp(0, 1023) as u16)
            .collect();
        let mut freq = vec![0u64; 1024];
        for &s in &syms {
            freq[s as usize] += 1;
        }
        let book = CanonicalCodebook::from_lengths(&build_lengths(&freq)).unwrap();
        (syms, book)
    }

    #[test]
    fn fused_matches_two_phase() {
        let (syms, book) = setup(50_000);
        let fused = deflate_chunks(&syms, &book, 4096, 4);
        let enc32 = encode_fixed_u32(&syms, &book, 4);
        let two32 = deflate_fixed_u32(&enc32, 4096, 4);
        assert_eq!(fused, two32);
        let enc64 = encode_fixed_u64(&syms, &book, 4);
        let two64 = deflate_fixed_u64(&enc64, 4096, 4);
        assert_eq!(fused, two64);
    }

    #[test]
    fn total_bits_is_exact() {
        let (syms, book) = setup(10_000);
        let s = deflate_chunks(&syms, &book, 1000, 2);
        assert_eq!(s.total_bits(), encoded_bits(&syms, &book));
        assert_eq!(s.total_symbols(), 10_000);
        assert_eq!(s.chunks.len(), 10);
    }

    #[test]
    fn chunk_boundaries_cover_tail() {
        let (syms, book) = setup(1001);
        let s = deflate_chunks(&syms, &book, 100, 3);
        assert_eq!(s.chunks.len(), 11);
        assert_eq!(s.chunks.last().unwrap().symbols, 1);
    }

    #[test]
    fn gap_deflate_is_bit_identical_and_table_is_exact() {
        let (syms, book) = setup(GAP_SUBCHUNK * 3 + 777);
        let plain = deflate_one(&syms, &book);
        let (gapped, gaps) = deflate_one_gap(&syms, &book);
        assert_eq!(plain, gapped);
        assert_eq!(gaps.len(), 4);
        assert_eq!(gaps[0], (0, GAP_SUBCHUNK as u32));
        assert_eq!(gaps[3].1, 777);
        assert_eq!(gaps.iter().map(|&(_, c)| c as u64).sum::<u64>(), syms.len() as u64);
        // each offset is exactly where a prefix deflate ends
        for (si, &(off, _)) in gaps.iter().enumerate() {
            let prefix = deflate_one(&syms[..si * GAP_SUBCHUNK], &book);
            assert_eq!(off, prefix.bits, "subchunk {si}");
        }
        // small chunks carry no table
        let (_, empty) = deflate_one_gap(&syms[..GAP_SUBCHUNK], &book);
        assert!(empty.is_empty());
    }

    #[test]
    fn parallelism_is_deterministic() {
        let (syms, book) = setup(30_000);
        let a = deflate_chunks(&syms, &book, 2048, 1);
        let b = deflate_chunks(&syms, &book, 2048, 8);
        assert_eq!(a, b);
    }
}
