//! Huffman tree construction → codeword bit lengths (paper §3.2.2).
//!
//! The paper builds the tree sequentially on one GPU thread to avoid
//! host↔device transfers; we build sequentially on the coordinator thread
//! (O(k log k), k = dict size ≤ 65536 — Table 3 measures this cost).
//! Only bit *lengths* are needed downstream: the canonical codebook
//! (codebook.rs) derives the actual codewords.

/// Build canonical Huffman code lengths from symbol frequencies.
/// Zero-frequency symbols get length 0 (no codeword).
pub fn build_lengths(freq: &[u64]) -> Vec<u8> {
    let k = freq.len();
    let mut lengths = vec![0u8; k];
    let present: Vec<usize> = (0..k).filter(|&i| freq[i] > 0).collect();
    match present.len() {
        0 => return lengths,
        1 => {
            // A single distinct symbol still needs one bit on the wire.
            lengths[present[0]] = 1;
            return lengths;
        }
        _ => {}
    }

    // Two-queue O(k log k) after an initial sort: leaves ascending by freq.
    let mut leaves: Vec<(u64, usize)> = present.iter().map(|&i| (freq[i], i)).collect();
    leaves.sort_unstable();

    // Nodes: (freq, id). Internal nodes get ids >= k.
    let mut parent = vec![usize::MAX; 2 * leaves.len()];
    let mut node_of_leaf = vec![usize::MAX; leaves.len()];
    let mut internal: std::collections::VecDeque<(u64, usize)> = Default::default();
    let mut leaf_q: std::collections::VecDeque<(u64, usize)> = Default::default();
    for (slot, &(f, _sym)) in leaves.iter().enumerate() {
        node_of_leaf[slot] = slot;
        leaf_q.push_back((f, slot));
    }
    let mut next_id = leaves.len();

    let pop_min = |leaf_q: &mut std::collections::VecDeque<(u64, usize)>,
                       internal: &mut std::collections::VecDeque<(u64, usize)>|
     -> (u64, usize) {
        match (leaf_q.front().copied(), internal.front().copied()) {
            (Some(l), Some(i)) => {
                if l.0 <= i.0 {
                    leaf_q.pop_front().unwrap()
                } else {
                    internal.pop_front().unwrap()
                }
            }
            (Some(_), None) => leaf_q.pop_front().unwrap(),
            (None, Some(_)) => internal.pop_front().unwrap(),
            (None, None) => unreachable!(),
        }
    };

    let total_nodes = 2 * leaves.len() - 1;
    while next_id < total_nodes {
        let a = pop_min(&mut leaf_q, &mut internal);
        let b = pop_min(&mut leaf_q, &mut internal);
        parent[a.1] = next_id;
        parent[b.1] = next_id;
        internal.push_back((a.0 + b.0, next_id));
        next_id += 1;
    }

    // Depth of each leaf = codeword length.
    for (slot, &(_f, sym)) in leaves.iter().enumerate() {
        let mut depth = 0u8;
        let mut node = node_of_leaf[slot];
        while parent[node] != usize::MAX {
            node = parent[node];
            depth += 1;
        }
        lengths[sym] = depth;
    }
    lengths
}

/// Kraft sum check: sum(2^-len) must equal 1 for a complete prefix code.
pub fn kraft_complete(lengths: &[u8]) -> bool {
    let mut sum = 0u128;
    let unit = 1u128 << 64;
    let mut any = false;
    for &l in lengths {
        if l > 0 {
            any = true;
            sum += unit >> l;
        }
    }
    !any || sum == unit || lengths.iter().filter(|&&l| l > 0).count() == 1
}

/// Shannon entropy (bits/symbol) of a frequency table — the lower bound the
/// Huffman coder should sit within ~1 bit of.
pub fn entropy_bits(freq: &[u64]) -> f64 {
    let total: u64 = freq.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    freq.iter()
        .filter(|&&f| f > 0)
        .map(|&f| {
            let p = f as f64 / t;
            -p * p.log2()
        })
        .sum()
}

/// Average codeword length in bits under `lengths` for `freq`.
pub fn average_length(freq: &[u64], lengths: &[u8]) -> f64 {
    let total: u64 = freq.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let bits: u128 = freq
        .iter()
        .zip(lengths)
        .map(|(&f, &l)| f as u128 * l as u128)
        .sum();
    bits as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn known_small_tree() {
        // freqs 1,1,2,4: lengths 3,3,2,1
        let lengths = build_lengths(&[1, 1, 2, 4]);
        assert_eq!(lengths, vec![3, 3, 2, 1]);
    }

    #[test]
    fn single_symbol_gets_one_bit() {
        let lengths = build_lengths(&[0, 7, 0]);
        assert_eq!(lengths, vec![0, 1, 0]);
    }

    #[test]
    fn empty_histogram() {
        assert_eq!(build_lengths(&[0, 0, 0]), vec![0, 0, 0]);
    }

    #[test]
    fn kraft_holds_on_random_histograms() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let k = 2 + rng.below(1024) as usize;
            let freq: Vec<u64> = (0..k)
                .map(|_| if rng.f32() < 0.3 { 0 } else { rng.below(10_000) + 1 })
                .collect();
            let lengths = build_lengths(&freq);
            assert!(kraft_complete(&lengths));
            // zero-freq symbols get no code; present symbols do
            for (f, l) in freq.iter().zip(&lengths) {
                assert_eq!(*f == 0, *l == 0);
            }
        }
    }

    #[test]
    fn optimality_within_one_bit_of_entropy() {
        let mut rng = Rng::new(4);
        let freq: Vec<u64> = (0..1024)
            .map(|i| {
                let z = (i as f64 - 512.0) / 12.0;
                let f = (1e6 * (-z * z / 2.0).exp()) as u64;
                f + (rng.below(3))
            })
            .collect();
        let lengths = build_lengths(&freq);
        let h = entropy_bits(&freq);
        let avg = average_length(&freq, &lengths);
        assert!(avg >= h - 1e-9, "avg {avg} entropy {h}");
        assert!(avg <= h + 1.0, "avg {avg} entropy {h}");
    }

    #[test]
    fn skewed_hist_long_codes_bounded() {
        // Fibonacci-like frequencies force deep trees; depth must stay < 64.
        let mut freq = vec![0u64; 40];
        let (mut a, mut b) = (1u64, 1u64);
        for f in freq.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let lengths = build_lengths(&freq);
        assert!(*lengths.iter().max().unwrap() < 64);
        assert!(kraft_complete(&lengths));
    }
}
