//! Offline stand-in for the small slice of the `xla` crate API that
//! [`super::pjrt`] uses. Compiled only when the `pjrt` cargo feature is
//! off, so the crate builds on machines without the xla_extension native
//! library. Every entry point fails with a clear runtime error; the
//! coordinator's fallback path then selects the bit-exact CPU mirror.

use std::fmt;

#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> Result<T> {
    Err(XlaError(
        "PJRT runtime not compiled in (rebuild with `--features pjrt` and the \
         xla_extension library); use the CPU backend instead"
            .into(),
    ))
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("PJRT runtime not compiled in"));
    }
}
