//! L3 ↔ L2 bridge: load the AOT-compiled HLO executables and run them via
//! the PJRT C API (`xla` crate), or fall back to the bit-exact CPU mirror.
//!
//! The PJRT client and its compiled executables live on one dedicated
//! engine thread ([`pjrt::Engine`]) — the software analogue of the paper's
//! single V100 device: pipeline stages submit quant/recon jobs over a
//! bounded channel and block on replies, which also serializes device
//! access exactly like a CUDA stream would.

pub mod artifacts;
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla_stub;

/// Whether this build links the real PJRT runtime (`pjrt` cargo feature).
/// Without it, `BackendKind::Pjrt` fails at engine start with a clear
/// message and fallback-aware callers drop to the CPU mirror.
pub const fn pjrt_compiled() -> bool {
    cfg!(feature = "pjrt")
}

use anyhow::Result;

use crate::config::{BackendKind, CuszConfig};
use crate::sz::blocks::SlabSpec;
use crate::sz::dual_quant;

pub use artifacts::{ArtifactManifest, ExecutableMeta};

/// A quantization engine: compress (dual-quant + histogram) and decompress
/// (inverse Lorenzo) over fixed-shape slabs.
pub trait QuantEngine: Send + Sync {
    /// data f32[slab] -> delta i32[slab] (DUAL-QUANT).
    fn compress_slab(&self, spec: &SlabSpec, data: &[f32], eb: f32) -> Result<Vec<i32>>;
    /// patched delta i32[slab] -> f32[slab].
    fn decompress_slab(&self, spec: &SlabSpec, delta: &[i32], eb: f32) -> Result<Vec<f32>>;
    fn name(&self) -> &'static str;

    /// The paper's device-side histogram kernel (§3.2.1), exposed for the
    /// breakdown bench and kernel cross-validation; the production path
    /// fuses histogramming into postquant at L3 (EXPERIMENTS.md §Perf).
    fn device_histogram(&self, spec: &SlabSpec, codes: &[i32], dict_size: usize) -> Result<Vec<u32>> {
        let _ = spec;
        let mut hist = vec![0u32; dict_size];
        for &c in codes {
            hist[c as usize] += 1;
        }
        Ok(hist)
    }

    /// Full per-slab compression product (delta + codes + hist + outliers).
    /// Default derives everything from the delta contract in one fused
    /// pass; the CPU mirror overrides with its fully-fused kernel.
    fn compress_slab_full(
        &self,
        spec: &SlabSpec,
        data: &[f32],
        eb: f32,
        dict_size: usize,
    ) -> Result<dual_quant::SlabCompressed> {
        let radius = (dict_size / 2) as i32;
        let delta = self.compress_slab(spec, data, eb)?;
        let mut codes = vec![0u16; delta.len()];
        let mut hist = vec![0u32; dict_size];
        let mut outliers = Vec::new();
        for (i, (&dv, c)) in delta.iter().zip(codes.iter_mut()).enumerate() {
            let code = crate::sz::code_of_delta(dv, radius);
            *c = code;
            hist[code as usize] += 1;
            if code == 0 {
                outliers.push((i as u32, dv));
            }
        }
        Ok(dual_quant::SlabCompressed { delta, codes, hist, outliers })
    }

    /// Owned-buffer decompression: engines that can reconstruct in place
    /// (CPU) override to avoid copies; default borrows.
    fn decompress_slab_owned(&self, spec: &SlabSpec, delta: Vec<i32>, eb: f32) -> Result<Vec<f32>> {
        self.decompress_slab(spec, &delta, eb)
    }

    /// Buffer-to-buffer decompression: reconstruct into a caller-provided
    /// output, consuming `delta` as scratch — the fused decompress pass's
    /// allocation-free entry point (both buffers arena-loaned). The
    /// default copies through [`QuantEngine::decompress_slab`]; the CPU
    /// mirror overrides with the true in-place kernel.
    fn decompress_slab_into(
        &self,
        spec: &SlabSpec,
        delta: &mut [i32],
        eb: f32,
        out: &mut [f32],
    ) -> Result<()> {
        let v = self.decompress_slab(spec, delta, eb)?;
        anyhow::ensure!(
            v.len() == out.len(),
            "engine produced {} values for a {}-element slab",
            v.len(),
            out.len()
        );
        out.copy_from_slice(&v);
        Ok(())
    }
}

/// Pure-Rust engine (Algorithm 2 mirror). Bit-exact with the PJRT path.
pub struct CpuEngine {
    pub dict_size: usize,
}

impl QuantEngine for CpuEngine {
    fn compress_slab(&self, spec: &SlabSpec, data: &[f32], eb: f32) -> Result<Vec<i32>> {
        Ok(dual_quant::dual_quant_delta(data, spec, eb))
    }

    fn decompress_slab(&self, spec: &SlabSpec, delta: &[i32], eb: f32) -> Result<Vec<f32>> {
        Ok(dual_quant::reconstruct_slab(delta, spec, eb))
    }

    fn compress_slab_full(
        &self,
        spec: &SlabSpec,
        data: &[f32],
        eb: f32,
        dict_size: usize,
    ) -> Result<dual_quant::SlabCompressed> {
        Ok(dual_quant::dual_quant_full(data, spec, eb, dict_size))
    }

    fn decompress_slab_owned(&self, spec: &SlabSpec, delta: Vec<i32>, eb: f32) -> Result<Vec<f32>> {
        Ok(dual_quant::reconstruct_slab_owned(delta, spec, eb))
    }

    fn decompress_slab_into(
        &self,
        spec: &SlabSpec,
        delta: &mut [i32],
        eb: f32,
        out: &mut [f32],
    ) -> Result<()> {
        dual_quant::reconstruct_slab_into(delta, spec, eb, out);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "cpu"
    }
}

/// Build the engine selected by the config. PJRT requires artifacts; if
/// they are missing, an error is returned (callers may retry with Cpu).
pub fn build_engine(cfg: &CuszConfig) -> Result<Box<dyn QuantEngine>> {
    match cfg.backend {
        BackendKind::Cpu => Ok(Box::new(CpuEngine { dict_size: cfg.dict_size })),
        BackendKind::Pjrt => {
            let manifest = ArtifactManifest::load(&cfg.artifacts_dir)?;
            anyhow::ensure!(
                manifest.dict_size() == cfg.dict_size,
                "artifacts compiled for dict_size {}, config wants {}",
                manifest.dict_size(),
                cfg.dict_size
            );
            Ok(Box::new(pjrt::PjrtEngine::start(manifest)?))
        }
    }
}
