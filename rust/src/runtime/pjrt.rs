//! PJRT engine: a dedicated thread owning the PJRT client and the compiled
//! AOT executables, serving quant/recon jobs over channels.
//!
//! Interchange is HLO text (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids (see DESIGN.md §1).

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

use anyhow::{anyhow, Context, Result};

use super::artifacts::ArtifactManifest;
use super::QuantEngine;
use crate::sz::blocks::SlabSpec;

// With the `pjrt` feature the `xla` crate provides the runtime; without
// it the in-tree stub satisfies the same API and errors at start-up.
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

enum Job {
    Compress {
        variant: String,
        data: Vec<f32>,
        eb: f32,
        reply: SyncSender<Result<Vec<i32>>>,
    },
    Histogram {
        variant: String,
        codes: Vec<i32>,
        reply: SyncSender<Result<Vec<u32>>>,
    },
    Decompress {
        variant: String,
        delta: Vec<i32>,
        eb: f32,
        reply: SyncSender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Handle to the engine thread. Cloneable; all clones feed one device queue.
pub struct PjrtEngine {
    tx: SyncSender<Job>,
    handle: Option<std::thread::JoinHandle<()>>,
    platform: String,
}

impl PjrtEngine {
    /// Start the engine thread and eagerly verify the client comes up.
    pub fn start(manifest: ArtifactManifest) -> Result<Self> {
        let (tx, rx) = sync_channel::<Job>(8);
        let (ready_tx, ready_rx) = sync_channel::<Result<String>>(1);
        let handle = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(manifest, rx, ready_tx))
            .context("spawning pjrt engine thread")?;
        let platform = ready_rx
            .recv()
            .context("engine thread died during init")??;
        Ok(PjrtEngine { tx, handle: Some(handle), platform })
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }
}

impl Drop for PjrtEngine {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl QuantEngine for PjrtEngine {
    fn compress_slab(&self, spec: &SlabSpec, data: &[f32], eb: f32) -> Result<Vec<i32>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Job::Compress { variant: spec.name.clone(), data: data.to_vec(), eb, reply })
            .map_err(|_| anyhow!("pjrt engine gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt engine dropped reply"))?
    }

    fn device_histogram(&self, spec: &SlabSpec, codes: &[i32], _dict: usize) -> Result<Vec<u32>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Job::Histogram { variant: spec.name.clone(), codes: codes.to_vec(), reply })
            .map_err(|_| anyhow!("pjrt engine gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt engine dropped reply"))?
    }

    fn decompress_slab(&self, spec: &SlabSpec, delta: &[i32], eb: f32) -> Result<Vec<f32>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Job::Decompress { variant: spec.name.clone(), delta: delta.to_vec(), eb, reply })
            .map_err(|_| anyhow!("pjrt engine gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt engine dropped reply"))?
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

struct EngineState {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: HashMap<(String, String), xla::PjRtLoadedExecutable>,
}

impl EngineState {
    fn executable(&mut self, op: &str, variant: &str) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (op.to_string(), variant.to_string());
        if !self.cache.contains_key(&key) {
            let meta = self
                .manifest
                .find(op, variant)
                .with_context(|| format!("no artifact for {op}/{variant}"))?;
            let path = meta
                .file
                .to_str()
                .context("artifact path not utf-8")?
                .to_string();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("PJRT compile of {op}/{variant}"))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }
}

fn engine_main(
    manifest: ArtifactManifest,
    rx: Receiver<Job>,
    ready: SyncSender<Result<String>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PJRT client init: {e}")));
            return;
        }
    };
    let platform = client.platform_name();
    let mut state = EngineState { client, manifest, cache: HashMap::new() };
    let _ = ready.send(Ok(platform));

    for job in rx {
        match job {
            Job::Shutdown => break,
            Job::Compress { variant, data, eb, reply } => {
                let _ = reply.send(run_compress(&mut state, &variant, &data, eb));
            }
            Job::Histogram { variant, codes, reply } => {
                let _ = reply.send(run_histogram(&mut state, &variant, &codes));
            }
            Job::Decompress { variant, delta, eb, reply } => {
                let _ = reply.send(run_decompress(&mut state, &variant, &delta, eb));
            }
        }
    }
}

fn shape_i64(meta_shape: &[usize]) -> Vec<i64> {
    meta_shape.iter().map(|&d| d as i64).collect()
}

fn run_compress(state: &mut EngineState, variant: &str, data: &[f32], eb: f32) -> Result<Vec<i32>> {
    let meta_shape = state
        .manifest
        .find("compress", variant)
        .with_context(|| format!("variant {variant}"))?
        .shape
        .clone();
    let n: usize = meta_shape.iter().product();
    anyhow::ensure!(data.len() == n, "slab size mismatch: {} vs {n}", data.len());

    let x = xla::Literal::vec1(data);
    let x = if meta_shape.len() > 1 { x.reshape(&shape_i64(&meta_shape))? } else { x };
    let ebl = xla::Literal::vec1(&[eb]);

    let exe = state.executable("compress", variant)?;
    let result = exe.execute::<xla::Literal>(&[x, ebl])?[0][0].to_literal_sync()?;
    let delta_l = result.to_tuple1()?;
    Ok(delta_l.to_vec::<i32>()?)
}

fn run_histogram(state: &mut EngineState, variant: &str, codes: &[i32]) -> Result<Vec<u32>> {
    let meta_shape = state
        .manifest
        .find("histogram", variant)
        .with_context(|| format!("variant {variant}"))?
        .shape
        .clone();
    let n: usize = meta_shape.iter().product();
    anyhow::ensure!(codes.len() == n, "slab size mismatch: {} vs {n}", codes.len());

    let x = xla::Literal::vec1(codes);
    let x = if meta_shape.len() > 1 { x.reshape(&shape_i64(&meta_shape))? } else { x };

    // note: jax prunes the unused eb parameter from the histogram graph,
    // so the compiled executable takes exactly one buffer
    let exe = state.executable("histogram", variant)?;
    let result = exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
    let hist_l = result.to_tuple1()?;
    let hist_i = hist_l.to_vec::<i32>()?;
    Ok(hist_i.into_iter().map(|v| v as u32).collect())
}

fn run_decompress(state: &mut EngineState, variant: &str, delta: &[i32], eb: f32) -> Result<Vec<f32>> {
    let meta_shape = state
        .manifest
        .find("decompress", variant)
        .with_context(|| format!("variant {variant}"))?
        .shape
        .clone();
    let n: usize = meta_shape.iter().product();
    anyhow::ensure!(delta.len() == n, "slab size mismatch: {} vs {n}", delta.len());

    let x = xla::Literal::vec1(delta);
    let x = if meta_shape.len() > 1 { x.reshape(&shape_i64(&meta_shape))? } else { x };
    let ebl = xla::Literal::vec1(&[eb]);

    let exe = state.executable("decompress", variant)?;
    let result = exe.execute::<xla::Literal>(&[x, ebl])?[0][0].to_literal_sync()?;
    let out = result.to_tuple1()?;
    Ok(out.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    //! PJRT tests live in rust/tests/pjrt_integration.rs (they need the
    //! artifacts directory); unit tests here cover only handle plumbing.

    #[test]
    fn missing_artifacts_error_is_clean() {
        let dir = std::path::Path::new("/nonexistent-cusz-artifacts");
        assert!(super::ArtifactManifest::load(dir).is_err());
    }
}
