//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. aot.py emits `manifest.tsv` (one row per HLO executable)
//! next to the `*.hlo.txt` files; this module parses it and selects the
//! right slab variant for a field.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::sz::blocks::SlabSpec;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecutableMeta {
    pub op: String,
    pub variant: String,
    pub file: PathBuf,
    pub shape: Vec<usize>,
    pub block: Vec<usize>,
    pub strips: usize,
    pub dict_size: usize,
    pub radius: i32,
    pub sha256: String,
}

impl ExecutableMeta {
    pub fn slab_spec(&self) -> SlabSpec {
        SlabSpec::new(&self.variant, &self.shape, &self.block)
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub executables: Vec<ExecutableMeta>,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let tsv = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&tsv)
            .with_context(|| format!("reading {} (run `make artifacts`)", tsv.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().context("empty manifest")?;
        let cols: Vec<&str> = header.split('\t').collect();
        let idx = |name: &str| -> Result<usize> {
            cols.iter()
                .position(|c| *c == name)
                .with_context(|| format!("manifest missing column {name}"))
        };
        let (i_op, i_var, i_file, i_shape, i_block, i_strips, i_dict, i_radius, i_sha) = (
            idx("op")?,
            idx("variant")?,
            idx("file")?,
            idx("shape")?,
            idx("block")?,
            idx("strips")?,
            idx("dict_size")?,
            idx("radius")?,
            idx("sha256")?,
        );
        let mut executables = Vec::new();
        for (ln, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() < cols.len() {
                bail!("manifest row {} malformed: {line:?}", ln + 2);
            }
            let parse_list = |s: &str| -> Result<Vec<usize>> {
                s.split(',').map(|x| x.parse::<usize>().context("int list")).collect()
            };
            executables.push(ExecutableMeta {
                op: f[i_op].to_string(),
                variant: f[i_var].to_string(),
                file: dir.join(f[i_file]),
                shape: parse_list(f[i_shape])?,
                block: parse_list(f[i_block])?,
                strips: f[i_strips].parse()?,
                dict_size: f[i_dict].parse()?,
                radius: f[i_radius].parse()?,
                sha256: f[i_sha].to_string(),
            });
        }
        if executables.is_empty() {
            bail!("manifest has no executables");
        }
        Ok(ArtifactManifest { dir: dir.to_path_buf(), executables })
    }

    pub fn dict_size(&self) -> usize {
        self.executables.first().map(|e| e.dict_size).unwrap_or(1024)
    }

    pub fn find(&self, op: &str, variant: &str) -> Option<&ExecutableMeta> {
        self.executables.iter().find(|e| e.op == op && e.variant == variant)
    }

    /// Pick the slab variant for a field's kernel dims: same padded-volume
    /// policy as `sz::blocks::select_spec`, over the manifest's variants.
    pub fn select_variant(&self, kernel_dims: &[usize]) -> Result<&ExecutableMeta> {
        self.executables
            .iter()
            .filter(|e| e.op == "compress" && e.shape.len() == kernel_dims.len())
            .min_by_key(|e| {
                let spec = e.slab_spec();
                (crate::sz::blocks::padded_volume(kernel_dims, &spec), usize::MAX - spec.len())
            })
            .with_context(|| format!("no artifact variant for {}D fields", kernel_dims.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "op\tvariant\tfile\tshape\tblock\tstrips\tdict_size\tradius\tsha256\n\
compress\t1d_64k\tcompress_1d_64k.hlo.txt\t65536\t32\t8\t1024\t512\tabc\n\
decompress\t1d_64k\tdecompress_1d_64k.hlo.txt\t65536\t32\t8\t1024\t512\tdef\n\
compress\t1d_1m\tcompress_1d_1m.hlo.txt\t1048576\t32\t8\t1024\t512\tghi\n\
compress\t2d_256\tcompress_2d_256.hlo.txt\t256,256\t16,16\t8\t1024\t512\tjkl\n";

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.executables.len(), 4);
        assert_eq!(m.dict_size(), 1024);
        let e = m.find("compress", "2d_256").unwrap();
        assert_eq!(e.shape, vec![256, 256]);
        assert_eq!(e.block, vec![16, 16]);
    }

    #[test]
    fn variant_selection_prefers_fitting_slab() {
        let m = ArtifactManifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        // tiny 1D field -> small variant
        assert_eq!(m.select_variant(&[10_000]).unwrap().variant, "1d_64k");
        // exact multiple of both slab sizes -> tie on padding, larger slab
        // wins (fewer dispatches)
        assert_eq!(m.select_variant(&[1 << 21]).unwrap().variant, "1d_1m");
        // 2D field -> the only 2D variant
        assert_eq!(m.select_variant(&[100, 100]).unwrap().variant, "2d_256");
        // no 3D variant in sample
        assert!(m.select_variant(&[8, 8, 8]).is_err());
    }

    #[test]
    fn missing_column_is_error() {
        assert!(ArtifactManifest::parse(Path::new("/t"), "op\tvariant\nx\ty\n").is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if let Ok(m) = ArtifactManifest::load(&dir) {
            assert!(m.executables.len() >= 2);
            for e in &m.executables {
                assert!(e.file.exists(), "{} missing", e.file.display());
                assert_eq!(e.dict_size, 1024);
            }
        }
    }
}
