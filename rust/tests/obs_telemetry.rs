//! Telemetry-layer integration locks: streaming-histogram percentiles vs
//! a sorted oracle, deterministic multi-thread counter/span merging, the
//! roundtrip-records-every-documented-stage regression, registry-backed
//! codec counters, and snapshot/exposition shape.

use cusz::codec::{codec_counter_keys, stage_for, EncodeContext, EncoderKind};
use cusz::config::{BackendKind, CodewordRepr, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
use cusz::obs::{self, keys, Histogram, Registry};
use cusz::util::prng::Rng;

fn cpu_coordinator() -> Coordinator {
    Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::ValRel(1e-4),
        ..Default::default()
    })
    .unwrap()
}

/// Exact percentile over a sorted sample — the oracle the streaming
/// log2-bucketed histogram is held against.
fn oracle_percentile(sorted: &[u64], q: f64) -> f64 {
    let rank = q * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] as f64 * (1.0 - frac) + sorted[hi] as f64 * frac
}

#[test]
fn histogram_percentiles_track_sorted_oracle() {
    // latency-shaped samples: a lognormal-ish body with a heavy tail,
    // spanning several powers of two — the regime the quarter-decade
    // sub-bucketing must hold its <= 12.5% midpoint error bound in
    let mut rng = Rng::new(99);
    let mut values: Vec<u64> = (0..20_000)
        .map(|_| {
            let base = (rng.normal().mul_add(0.6, 11.0)).exp(); // e^11 ~ 60k ns
            base.max(1.0) as u64
        })
        .collect();
    let hist = Histogram::new();
    for &v in &values {
        hist.record(v);
    }
    values.sort_unstable();
    let snap = hist.snapshot();
    assert_eq!(snap.count, 20_000);
    assert_eq!(snap.min, values[0]);
    assert_eq!(snap.max, *values.last().unwrap());
    for q in [0.50, 0.95, 0.99] {
        let oracle = oracle_percentile(&values, q);
        let est = snap.percentile(q);
        let rel = (est - oracle).abs() / oracle;
        // bucket midpoint error bound is 12.5%; leave interpolation slack
        assert!(rel <= 0.15, "p{q}: est {est:.0} vs oracle {oracle:.0} (rel {rel:.3})");
    }

    // the linear region (< 16) is exact, so small-value percentiles are too
    let small = Histogram::new();
    for v in 0..16u64 {
        small.record(v);
    }
    let s = small.snapshot();
    assert_eq!(s.percentile(0.0), 0.0);
    assert_eq!(s.percentile(1.0), 15.0);
}

#[test]
fn eight_thread_fixed_workload_merges_exactly() {
    // a private registry so parallel tests can't perturb the counts
    let reg = Registry::new();
    std::thread::scope(|s| {
        for t in 0..8 {
            let reg = &reg;
            s.spawn(move || {
                for i in 0..100u64 {
                    reg.add("t.jobs", 1);
                    reg.add("t.bytes", 64);
                    let span = reg.span("t.work").with_bytes(32);
                    drop(span);
                    reg.histogram("t.lat").record(t * 100 + i + 1);
                }
            });
        }
    });
    assert_eq!(reg.counter_value("t.jobs"), 800);
    assert_eq!(reg.counter_value("t.bytes"), 800 * 64);
    let snap = reg.snapshot();
    let work = snap.stage("t.work").unwrap();
    assert_eq!(work.calls, 800);
    assert_eq!(work.bytes, 800 * 32);
    assert!(work.ns > 0);
    let lat = snap.histogram("t.lat").unwrap();
    assert_eq!(lat.count, 800);
    assert_eq!(lat.min, 1);
    assert_eq!(lat.max, 800);
    // reset zeroes in place; keys survive for cached static call sites
    reg.reset();
    assert_eq!(reg.counter_value("t.jobs"), 0);
    assert_eq!(reg.snapshot().stage("t.work").unwrap().calls, 0);
}

#[test]
fn roundtrip_records_every_documented_stage() {
    let reg = obs::global();
    let before: Vec<u64> = keys::DOCUMENTED_STAGES.iter().map(|k| reg.stage_ns(k)).collect();
    let fields_before = reg.counter_value("compress.fields");

    let coord = cpu_coordinator();
    let field = datagen::generate(Dataset::CesmAtm, "CLDHGH", 3);
    let (archive, _) = coord.compress_with_stats(&field).unwrap();
    let (out, _) = coord.decompress_with_stats(&archive).unwrap();
    assert_eq!(out.dims, field.dims);

    for (key, &b) in keys::DOCUMENTED_STAGES.iter().zip(&before) {
        let after = reg.stage_ns(key);
        assert!(after > b, "stage '{key}' recorded no time during a full roundtrip");
    }
    assert!(reg.counter_value("compress.fields") > fields_before);
    assert!(reg.counter_value("decompress.fields") > 0);
}

#[test]
fn instrumented_codec_stages_feed_backend_counters() {
    let mut rng = Rng::new(5);
    let dict = 1024usize;
    let symbols: Vec<u16> = (0..1 << 14).map(|_| rng.below(dict as u64) as u16).collect();
    let mut freq = vec![0u64; dict];
    for &s in &symbols {
        freq[s as usize] += 1;
    }
    let ctx = EncodeContext {
        dict_size: dict,
        chunk_symbols: 4096,
        threads: 2,
        codeword_repr: CodewordRepr::Adaptive,
        freq: &freq,
    };
    let reg = obs::global();
    for kind in EncoderKind::ALL {
        let k = codec_counter_keys(kind);
        let enc_syms0 = reg.counter_value(k.encode_symbols);
        let enc_ns0 = reg.counter_value(k.encode_ns);
        let dec_syms0 = reg.counter_value(k.decode_symbols);
        let stage = stage_for(kind);
        let enc = stage.encode(&symbols, &ctx).unwrap();
        let out = stage.decode(&enc.aux, &enc.stream, dict, 2, symbols.len()).unwrap();
        assert_eq!(out, symbols);
        assert_eq!(
            reg.counter_value(k.encode_symbols),
            enc_syms0 + symbols.len() as u64,
            "{} encode_symbols",
            kind.name()
        );
        assert!(reg.counter_value(k.encode_ns) > enc_ns0, "{} encode_ns", kind.name());
        assert_eq!(
            reg.counter_value(k.decode_symbols),
            dec_syms0 + symbols.len() as u64,
            "{} decode_symbols",
            kind.name()
        );
    }
}

#[test]
fn snapshot_and_exposition_carry_the_roundtrip() {
    let coord = cpu_coordinator();
    let field = datagen::generate(Dataset::Nyx, "baryon_density", 11);
    let (archive, _) = coord.compress_with_stats(&field).unwrap();
    coord.decompress_with_stats(&archive).unwrap();

    let snap = obs::global().snapshot();
    let json = snap.to_json();
    assert!(json.contains("\"schema\": \"cusz-metrics/v1\""));
    // every documented stage appears with non-zero time and bytes
    for key in keys::DOCUMENTED_STAGES {
        let st = snap.stage(key).unwrap_or_else(|| panic!("stage '{key}' missing"));
        assert!(st.ns > 0 && st.calls > 0 && st.bytes > 0, "stage '{key}' empty");
        assert!(st.gbps() > 0.0, "stage '{key}' has no throughput");
        assert!(json.contains(&format!("\"{key}\"")), "stage '{key}' not in JSON");
    }
    assert_eq!(json.matches('{').count(), json.matches('}').count());

    let text = obs::global().render_text();
    assert!(text.contains("cusz_stage_ns_total{stage=\"compress.predict_quant\"}"));
    assert!(text.contains("cusz_counter{name=\"compress.fields\"}"));
}
