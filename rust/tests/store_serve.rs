//! End-to-end acceptance for the store+serve subsystem: a multi-field
//! snapshot batched through the streaming pipeline into one `.cuszb`
//! bundle, then single-field random-access decompression with the error
//! bound verified — the serving-shaped analogue of the paper's
//! compress-every-field campaign loop.

use std::sync::Arc;

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
use cusz::field::Field;
use cusz::metrics;
use cusz::serve::{BatchCompressor, BatchConfig};
use cusz::store::Store;
use cusz::testkit::fields::{make, Regime};
use cusz::testkit::tmp_dir;

fn coordinator() -> Arc<Coordinator> {
    Arc::new(
        Coordinator::new(CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::ValRel(1e-3),
            threads: 1, // the batch layer supplies job concurrency
            ..Default::default()
        })
        .unwrap(),
    )
}

/// A snapshot of 9 fields: 8 synthetic across regimes and dimensionalities
/// plus one dataset-profile field.
fn snapshot() -> Vec<Field> {
    let mut fields = Vec::new();
    for i in 0..8u64 {
        let (name, dims): (String, Vec<usize>) = match i % 3 {
            0 => (format!("snap/line-{i}"), vec![20_000]),
            1 => (format!("snap/plane-{i}"), vec![128, 128]),
            _ => (format!("snap/cube-{i}"), vec![24, 32, 40]),
        };
        let n: usize = dims.iter().product();
        let data = make(Regime::ALL[(i % 3) as usize], n, i);
        fields.push(Field::new(name, dims, data).unwrap());
    }
    fields.push(datagen::generate(Dataset::CesmAtm, "CLDHGH", 7));
    fields
}

#[test]
fn batched_snapshot_roundtrips_via_random_access() {
    let dir = tmp_dir("accept-store-serve");
    let coord = coordinator();
    let originals = snapshot();
    assert!(originals.len() >= 8, "acceptance requires >= 8 fields");

    // --- batch-compress the whole snapshot into one bundle -------------
    let mut store = Store::create(&dir, 3).unwrap();
    let batch = BatchCompressor::new(
        Arc::clone(&coord),
        BatchConfig { workers: 4, queue_depth: 2, ..Default::default() },
    );
    let stats = batch.run_into_store(originals.clone(), &mut store).unwrap();
    assert_eq!(stats.jobs, originals.len());
    assert_eq!(stats.failed, 0, "errors: {:?}", stats.errors);
    assert_eq!(store.len(), originals.len());
    assert!(stats.compression_ratio() > 1.0);
    drop(store);

    // --- reopen from disk, single-field random access ------------------
    let store = Store::open(&dir).unwrap();
    store.verify().unwrap();
    let target = &originals[5]; // one named field, siblings untouched
    let archive = store.get(&target.name).unwrap();
    let restored = coord.decompress(&archive).unwrap();
    assert_eq!(restored.dims, target.dims);
    assert_eq!(
        metrics::verify_error_bound(&target.data, &restored.data, archive.header.abs_eb),
        None,
        "error bound violated for {}",
        target.name
    );

    // --- and every other field also honors its bound -------------------
    for f in &originals {
        let archive = store.get(&f.name).unwrap();
        let out = coord.decompress(&archive).unwrap();
        assert_eq!(
            metrics::verify_error_bound(&f.data, &out.data, archive.header.abs_eb),
            None,
            "error bound violated for {}",
            f.name
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn store_survives_rm_and_batch_append_cycles() {
    let dir = tmp_dir("accept-cycles");
    let coord = coordinator();
    let mut store = Store::create(&dir, 2).unwrap();
    let batch = BatchCompressor::new(Arc::clone(&coord), BatchConfig { workers: 2, queue_depth: 2, ..Default::default() });

    let first: Vec<Field> = snapshot().into_iter().take(4).collect();
    batch.run_into_store(first.clone(), &mut store).unwrap();
    store.remove(&first[1].name).unwrap();

    // a second batch streams into the same bundle alongside survivors
    let second: Vec<Field> = snapshot().into_iter().skip(4).collect();
    batch.run_into_store(second.clone(), &mut store).unwrap();

    drop(store);
    let store = Store::open(&dir).unwrap();
    assert_eq!(store.len(), first.len() - 1 + second.len());
    assert!(store.find(&first[1].name).is_none());
    for f in first.iter().take(1).chain(first.iter().skip(2)).chain(second.iter()) {
        let archive = store.get(&f.name).unwrap();
        let out = coord.decompress(&archive).unwrap();
        assert_eq!(
            metrics::verify_error_bound(&f.data, &out.data, archive.header.abs_eb),
            None,
            "{}",
            f.name
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
