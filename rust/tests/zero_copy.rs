//! Zero-copy encode path acceptance tests: exactly one serialization
//! pass (one lossless-tail encode) per compressed field, the streaming
//! writer's identities (`write_into` == `to_bytes`, `serialized_len` ==
//! `to_bytes().len()`) across the codec matrix, segmented-tail
//! corruption behavior, and end-to-end correctness when codec chunk
//! windows straddle slab boundaries (the `SymbolSource` stitch path).

use cusz::codec::{CodecGranularity, CodecSpec, EncoderChoice};
use cusz::config::{BackendKind, CuszConfig, ErrorBound, LosslessStage};
use cusz::container::{self, Archive};
use cusz::coordinator::Coordinator;
use cusz::field::Field;
use cusz::metrics;
use cusz::store::Store;
use cusz::testkit::fields::{make, Regime};
use cusz::testkit::tmp_dir;

const EB: f32 = 1e-3;

fn coordinator(codec: CodecSpec) -> Coordinator {
    Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(EB as f64),
        codec,
        ..Default::default()
    })
    .unwrap()
}

fn sample_field(n: usize, seed: u64) -> Field {
    Field::new(format!("zc-{seed}"), vec![n], make(Regime::Smooth, n, seed)).unwrap()
}

/// THE regression test for the old `compressed_bytes()` double
/// serialization: compressing one field (stats included) and landing its
/// bytes in a store must perform exactly ONE lossless-tail encode. The
/// probe is a thread-local counter in `container`, so concurrent tests
/// cannot pollute the delta.
#[test]
fn one_field_compression_is_one_tail_encode() {
    let coord = coordinator(CodecSpec {
        encoder: EncoderChoice::Huffman,
        lossless: LosslessStage::Zstd,
        ..Default::default()
    });
    let field = sample_field(40_000, 1);

    let before = container::lossless_tail_encodes();
    let compressed = coord.compress_encoded(&field).unwrap();
    assert_eq!(
        container::lossless_tail_encodes() - before,
        1,
        "compress_encoded (stats included) must encode the tail exactly once"
    );

    // landing the bytes in a bundle re-uses the same serialization
    let dir = tmp_dir("zero-copy-store");
    let mut store = Store::create(&dir, 1).unwrap();
    store
        .add_bytes(&compressed.archive.header.field_name, &compressed.bytes)
        .unwrap();
    assert_eq!(
        container::lossless_tail_encodes() - before,
        1,
        "the store append must not re-serialize"
    );

    // and the stats were priced off those very bytes
    assert_eq!(compressed.stats.compressed_bytes, compressed.bytes.len());
    let restored = coord.decompress(&store.get(&field.name).unwrap()).unwrap();
    assert_eq!(metrics::verify_error_bound(&field.data, &restored.data, EB), None);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The legacy `Store::add(&archive)` path streams one serialization
/// straight into the shard — also a single tail encode, with no payload
/// buffer in between.
#[test]
fn store_add_streams_a_single_serialization() {
    let coord = coordinator(CodecSpec {
        encoder: EncoderChoice::Fle,
        lossless: LosslessStage::Gzip,
        ..Default::default()
    });
    let field = sample_field(30_000, 2);
    let archive = coord.compress(&field).unwrap();

    let dir = tmp_dir("zero-copy-store-add");
    let mut store = Store::create(&dir, 1).unwrap();
    let before = container::lossless_tail_encodes();
    let entry = store.add(&archive).unwrap();
    assert_eq!(container::lossless_tail_encodes() - before, 1);
    assert_eq!(entry.len as usize, archive.serialized_len());

    // integrity survives the streamed write: CRC-checked read + decode
    let restored = store.get(&field.name).unwrap();
    assert_eq!(restored, archive);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn serialized_len_is_exact_across_the_codec_matrix() {
    let encoders = [
        EncoderChoice::Huffman,
        EncoderChoice::Fle,
        EncoderChoice::Rle,
        EncoderChoice::Auto,
    ];
    let tails = [LosslessStage::None, LosslessStage::Gzip, LosslessStage::Zstd];
    let grains = [CodecGranularity::Field, CodecGranularity::Chunk];
    let field = sample_field(50_000, 3);
    for encoder in encoders {
        for lossless in tails {
            for granularity in grains {
                let coord = coordinator(CodecSpec { encoder, lossless, granularity });
                let archive = coord.compress(&field).unwrap();
                let bytes = archive.to_bytes();
                assert_eq!(
                    archive.serialized_len(),
                    bytes.len(),
                    "{encoder:?}/{lossless:?}/{granularity:?}"
                );
                let mut streamed = Vec::new();
                archive.write_into(&mut streamed).unwrap();
                assert_eq!(streamed, bytes, "{encoder:?}/{lossless:?}/{granularity:?}");
            }
        }
    }
}

/// Chunk windows that straddle slab boundaries (chunk size not dividing
/// the slab length, multi-slab field) must roundtrip across every
/// backend — the `SymbolSource` stitch path end to end.
#[test]
fn straddling_chunk_windows_roundtrip() {
    let n = 1 << 17; // two 1d_64k slabs
    for encoder in [
        EncoderChoice::Huffman,
        EncoderChoice::Fle,
        EncoderChoice::Rle,
        EncoderChoice::Auto,
    ] {
        for granularity in [CodecGranularity::Field, CodecGranularity::Chunk] {
            let coord = Coordinator::new(CuszConfig {
                backend: BackendKind::Cpu,
                eb: ErrorBound::Abs(EB as f64),
                // 3000 does not divide 65536: windows straddle the slab
                // boundary and the tail chunk is irregular
                chunk_symbols: 3000,
                codec: CodecSpec { encoder, lossless: LosslessStage::Zstd, granularity },
                ..Default::default()
            })
            .unwrap();
            let field = sample_field(n, 7);
            let compressed = coord.compress_encoded(&field).unwrap();
            let restored = Archive::from_bytes(&compressed.bytes).unwrap();
            let out = coord.decompress(&restored).unwrap();
            assert_eq!(
                metrics::verify_error_bound(&field.data, &out.data, EB),
                None,
                "{encoder:?}/{granularity:?}"
            );
        }
    }
}

/// Corrupting a v3 segmented tail fails cleanly: truncations and bit
/// flips error (no panic), and a lying segment table cannot force an
/// allocation past the header-derived cap.
#[test]
fn segmented_tail_corruption_fails_cleanly() {
    let coord = coordinator(CodecSpec {
        encoder: EncoderChoice::Huffman,
        lossless: LosslessStage::Zstd,
        ..Default::default()
    });
    // big enough that the ~175 KB quant body still fits several probes
    let field = sample_field(1 << 16, 9);
    let bytes = coord.compress_encoded(&field).unwrap().bytes;
    assert!(Archive::from_bytes(&bytes).is_ok());

    // every truncation point errors, never panics
    for cut in [1usize, 9, 21, bytes.len() / 2, bytes.len() - 1] {
        assert!(Archive::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
    }
    // bit flips across the whole archive (magic, header, segment table,
    // segment payloads) are rejected
    for pos in (0..bytes.len()).step_by(bytes.len() / 23 + 1) {
        let mut flipped = bytes.clone();
        flipped[pos] ^= 0x10;
        assert!(Archive::from_bytes(&flipped).is_err(), "flip at {pos}");
    }
}
