//! Fault-injection battery: the failure modes a long-running service
//! actually meets — bit rot in a store shard between put and get, and a
//! worker poisoned mid-job — must surface as per-request errors while
//! the daemon keeps serving. Archive-level corruption is also locked
//! down directly (truncation, bit flips, garbage) so the wire and store
//! layers can rely on the container failing cleanly.

use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::Arc;
use std::time::Duration;

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::container::Archive;
use cusz::coordinator::Coordinator;
use cusz::field::Field;
use cusz::serve::wire::{Client, GetOutcome, PutOutcome};
use cusz::serve::{Daemon, DaemonConfig};
use cusz::store::Store;
use cusz::testkit::fields::{make, Regime};
use cusz::testkit::tmp_dir;

const TIMEOUT: Duration = Duration::from_secs(20);

fn coordinator() -> Arc<Coordinator> {
    Arc::new(
        Coordinator::new(CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::Abs(1e-2),
            threads: 1,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn sample_field(name: &str, i: usize) -> Field {
    Field::new(
        name.to_string(),
        vec![40, 40],
        make(Regime::ALL[i % Regime::ALL.len()], 40 * 40, i as u64),
    )
    .unwrap()
}

fn put_ok(client: &mut Client, field: &Field) {
    loop {
        match client.put(field).unwrap() {
            PutOutcome::Stored { .. } => return,
            PutOutcome::Busy => std::thread::sleep(Duration::from_millis(5)),
            other => panic!("put {}: {other:?}", field.name),
        }
    }
}

#[test]
fn corrupt_shard_between_put_and_get_is_a_per_request_error() {
    let dir = tmp_dir("fault-shard");
    let store = Store::create(&dir, 1).unwrap();
    let handle = Daemon::spawn(
        coordinator(),
        store,
        "127.0.0.1:0",
        DaemonConfig { workers: 1, ..Default::default() },
    )
    .unwrap();
    let mut client = Client::connect(&handle.addr().to_string(), TIMEOUT, TIMEOUT).unwrap();

    put_ok(&mut client, &sample_field("good", 0));
    put_ok(&mut client, &sample_field("victim", 1));

    // bit-rot the victim's payload on disk, between its put and its get:
    // a read-only Store::open sees the committed index (shard, offset,
    // len) the daemon is serving from
    {
        let snapshot = Store::open(&dir).unwrap();
        let entry = snapshot
            .list()
            .iter()
            .find(|e| e.name == "victim")
            .cloned()
            .expect("victim committed");
        let shard_path = dir.join(format!("shard-{:04}.cuszs", entry.shard));
        let mut f = std::fs::OpenOptions::new().read(true).write(true).open(shard_path).unwrap();
        f.seek(SeekFrom::Start(entry.offset + entry.len / 2)).unwrap();
        let mut byte = [0u8; 1];
        f.read_exact(&mut byte).unwrap();
        f.seek(SeekFrom::Start(entry.offset + entry.len / 2)).unwrap();
        f.write_all(&[byte[0] ^ 0xFF]).unwrap();
        f.flush().unwrap();
    }

    // the corrupted entry fails per-request, with a checked-read error
    match client.get("victim").unwrap() {
        GetOutcome::Failed(msg) => {
            assert!(
                msg.to_lowercase().contains("crc") || msg.to_lowercase().contains("corrupt"),
                "unexpected error text: {msg}"
            );
        }
        other => panic!("expected Failed for corrupted entry, got {other:?}"),
    }

    // the daemon is still up and other entries still serve
    client.ping().unwrap();
    match client.get("good").unwrap() {
        GetOutcome::Field(f) => assert_eq!(f.dims, vec![40, 40]),
        other => panic!("get good: {other:?}"),
    }
    // and PUTs still land after the fault
    put_ok(&mut client, &sample_field("after", 2));

    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.gets_failed, 1);
    assert_eq!(stats.gets, 1);
    assert_eq!(stats.put.jobs, 3);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn poisoned_worker_job_is_contained_and_drain_completes() {
    let dir = tmp_dir("fault-poison");
    let store = Store::create(&dir, 1).unwrap();
    let handle = Daemon::spawn(
        coordinator(),
        store,
        "127.0.0.1:0",
        DaemonConfig {
            workers: 1, // one worker: if the panic killed it, everything after would hang
            fault_panic_name: Some("poison".to_string()),
            ..Default::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&handle.addr().to_string(), TIMEOUT, TIMEOUT).unwrap();

    put_ok(&mut client, &sample_field("before", 0));

    // the injected panic inside the worker becomes a per-request error
    match client.put(&sample_field("poison", 1)).unwrap() {
        PutOutcome::Failed(msg) => {
            assert!(msg.contains("panicked"), "unexpected error text: {msg}")
        }
        other => panic!("expected Failed for poisoned job, got {other:?}"),
    }

    // the sole worker survived: later jobs on the same daemon complete
    put_ok(&mut client, &sample_field("after", 2));
    match client.get("after").unwrap() {
        GetOutcome::Field(_) => {}
        other => panic!("get after: {other:?}"),
    }

    // mid-drain poison: enqueue a poisoned and a healthy job, then drain —
    // the drain must finish both (error + success), not wedge
    let mut late = Client::connect(&handle.addr().to_string(), TIMEOUT, TIMEOUT).unwrap();
    let drain_probe = std::thread::spawn({
        let addr = handle.addr().to_string();
        move || {
            let mut c = Client::connect(&addr, TIMEOUT, TIMEOUT).unwrap();
            c.put(&sample_field("poison", 3))
        }
    });
    std::thread::sleep(Duration::from_millis(20));
    handle.trigger_drain();
    let late_result = late.put(&sample_field("late", 4));
    let probe_result = drain_probe.join().unwrap();
    // both requests got explicit outcomes (never hung, never dropped)
    assert!(probe_result.is_ok() || probe_result.is_err());
    drop(late_result);

    let stats = handle.wait().unwrap();
    assert!(stats.put.failed >= 1, "poisoned jobs must be recorded as failures");
    assert!(stats.put.errors.iter().any(|(name, e)| name == "poison" && e.contains("panicked")));
    assert!(stats.put.jobs >= 2);

    // store holds the healthy fields, never a half-written poisoned one
    let store = Store::open(&dir).unwrap();
    assert!(store.contains("before"));
    assert!(store.contains("after"));
    assert!(!store.contains("poison"));
    store.verify().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn archive_corruption_fails_cleanly_at_every_layer() {
    // the patterns a failure-injection example would demonstrate, locked
    // as a real test: decode of damaged containers must error, not panic
    let coord = coordinator();
    let field = sample_field("corrupt-me", 0);
    let bytes = coord.compress_encoded(&field).unwrap().bytes;

    // truncation at several depths
    for cut in [0usize, 1, 8, bytes.len() / 2, bytes.len() - 1] {
        let truncated = &bytes[..cut];
        assert!(
            Archive::from_bytes(truncated).is_err(),
            "truncated at {cut} must not decode"
        );
    }

    // single-bit flips across the container (header, sections, payload)
    let mut hits = 0;
    for pos in (0..bytes.len()).step_by((bytes.len() / 16).max(1)) {
        let mut damaged = bytes.clone();
        damaged[pos] ^= 0x01;
        match Archive::from_bytes(&damaged) {
            Err(_) => hits += 1,
            Ok(archive) => {
                // a flip the container checksums missed must still either
                // decode-fail or produce a wrong-but-bounded field, never
                // panic — exercising it is the assertion
                let _ = coord.decompress_with_threads(&archive, 1);
            }
        }
    }
    assert!(hits > 0, "no corruption detected across {} probes", bytes.len());

    // pure garbage
    assert!(Archive::from_bytes(&[0u8; 64]).is_err());
    assert!(Archive::from_bytes(b"not an archive at all").is_err());

    // a corrupted store entry is caught by the checked read path
    let dir = tmp_dir("fault-store-direct");
    let mut store = Store::create(&dir, 1).unwrap();
    store.add_bytes("x", &bytes).unwrap();
    let entry = store.list()[0].clone();
    let shard_path = dir.join(format!("shard-{:04}.cuszs", entry.shard));
    {
        let mut f = std::fs::OpenOptions::new().read(true).write(true).open(shard_path).unwrap();
        f.seek(SeekFrom::Start(entry.offset + entry.len / 3)).unwrap();
        let mut b = [0u8; 1];
        f.read_exact(&mut b).unwrap();
        f.seek(SeekFrom::Start(entry.offset + entry.len / 3)).unwrap();
        f.write_all(&[b[0] ^ 0x10]).unwrap();
    }
    assert!(store.get_bytes_checked("x").is_err());
    assert!(store.verify().is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn scrubber_quarantines_bit_rot_while_daemon_keeps_serving() {
    let dir = tmp_dir("fault-scrub");
    let store = Store::create(&dir, 1).unwrap();
    let handle = Daemon::spawn(
        coordinator(),
        store,
        "127.0.0.1:0",
        DaemonConfig {
            workers: 1,
            scrub_interval: Some(Duration::from_millis(5)),
            ..Default::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&handle.addr().to_string(), TIMEOUT, TIMEOUT).unwrap();

    put_ok(&mut client, &sample_field("good", 0));
    put_ok(&mut client, &sample_field("rotten", 1));

    // counters are process-global across the test binary: assert deltas
    let obs = cusz::obs::global();
    let corrupt_before = obs.counter_value(cusz::obs::keys::STORE_SCRUB_CORRUPT);
    let quarantined_before = obs.counter_value(cusz::obs::keys::STORE_SCRUB_QUARANTINED);
    let get_q_before = obs.counter_value(cusz::obs::keys::SERVE_DAEMON_GET_QUARANTINED);

    // bit-rot the rotten entry's payload on disk behind the daemon's back
    {
        let snapshot = Store::open(&dir).unwrap();
        let entry = snapshot
            .list()
            .iter()
            .find(|e| e.name == "rotten")
            .cloned()
            .expect("rotten committed");
        let shard_path = dir.join(format!("shard-{:04}.cuszs", entry.shard));
        let mut f =
            std::fs::OpenOptions::new().read(true).write(true).open(shard_path).unwrap();
        f.seek(SeekFrom::Start(entry.offset + entry.len / 2)).unwrap();
        let mut byte = [0u8; 1];
        f.read_exact(&mut byte).unwrap();
        f.seek(SeekFrom::Start(entry.offset + entry.len / 2)).unwrap();
        f.write_all(&[byte[0] ^ 0xFF]).unwrap();
        f.flush().unwrap();
    }

    // the background scrubber's round-robin reaches the rotten entry and
    // pulls it into quarantine; its GETs then answer the dedicated
    // QUARANTINED status (not SERVER_ERROR, not NOT_FOUND)
    let deadline = std::time::Instant::now() + TIMEOUT;
    loop {
        match client.get("rotten").unwrap() {
            GetOutcome::Quarantined => break,
            GetOutcome::Failed(_) | GetOutcome::Busy => {
                assert!(
                    std::time::Instant::now() < deadline,
                    "scrubber never quarantined the corrupt entry"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
            other => panic!("get rotten: {other:?}"),
        }
    }
    assert!(obs.counter_value(cusz::obs::keys::STORE_SCRUB_CORRUPT) > corrupt_before);
    assert!(obs.counter_value(cusz::obs::keys::STORE_SCRUB_QUARANTINED) > quarantined_before);
    assert!(obs.counter_value(cusz::obs::keys::SERVE_DAEMON_GET_QUARANTINED) > get_q_before);

    // the daemon is unaffected: pings, healthy GETs, and fresh PUTs work
    client.ping().unwrap();
    match client.get("good").unwrap() {
        GetOutcome::Field(f) => assert_eq!(f.dims, vec![40, 40]),
        other => panic!("get good: {other:?}"),
    }
    put_ok(&mut client, &sample_field("after", 2));

    // an upsert under the quarantined name supersedes the verdict
    put_ok(&mut client, &sample_field("rotten", 3));
    match client.get("rotten").unwrap() {
        GetOutcome::Field(f) => assert_eq!(f.dims, vec![40, 40]),
        other => panic!("get rotten after re-put: {other:?}"),
    }

    handle.shutdown().unwrap();
    // the quarantine is on disk: a cold writable open remembers nothing
    // for "rotten" (re-put cleared it) and the store fully verifies
    let store = Store::open_writable(&dir).unwrap();
    assert!(!store.is_quarantined("rotten"));
    store.verify().unwrap();
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}
