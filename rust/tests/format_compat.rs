//! Golden-fixture compatibility corpus: pre-built `CUSZA1` (format
//! version 0), `CUSZA2` (format version 1), `CUSZA3` (format version 3:
//! granularity byte, tag table, segmented lossless tail), and `CUSZA4`
//! (format version 4: per-chunk Huffman gap tables) archives plus a
//! `.cuszb` bundle, committed under `tests/fixtures/` with the exact
//! f32 field each one decodes to (see `fixtures/make_fixtures.py` for
//! provenance).
//!
//! Every fixture must keep decoding byte-for-byte under the current
//! code, and the uncompressed ones must re-serialize to their original
//! bytes — so a format bump (like this PR's `CUSZA4`) can never silently
//! orphan old payloads. If one of these tests fails, the format change
//! broke backward compatibility; fix the code, don't regenerate the
//! fixtures.

use std::path::PathBuf;

use cusz::codec::{CodecGranularity, EncoderKind};
use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::container::Archive;
use cusz::coordinator::Coordinator;
use cusz::metrics;
use cusz::store::Store;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn expected_field() -> Vec<f32> {
    let bytes = std::fs::read(fixture_path("expected/fixture_field.f32")).unwrap();
    assert_eq!(bytes.len() % 4, 0);
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn cpu_coordinator() -> Coordinator {
    Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(0.03125),
        ..Default::default()
    })
    .unwrap()
}

/// Decode one fixture and hold it to the corpus contract: parses, decodes
/// bit-for-bit to the committed field, and respects its recorded bound.
fn check_fixture(
    name: &str,
    version: u8,
    encoder: EncoderKind,
    granularity: CodecGranularity,
    expect_byte_stable: bool,
) -> Archive {
    let bytes = std::fs::read(fixture_path(name)).unwrap();
    let archive = Archive::from_bytes(&bytes)
        .unwrap_or_else(|e| panic!("{name}: no longer parses: {e:#}"));
    assert_eq!(archive.header.version, version, "{name}");
    assert_eq!(archive.header.encoder, encoder, "{name}");
    assert_eq!(archive.header.granularity, granularity, "{name}");
    assert_eq!(
        granularity == CodecGranularity::Chunk,
        !archive.chunk_tags.is_empty(),
        "{name}: tag table presence must match the granularity byte"
    );
    assert_eq!(Archive::peek_header(&bytes).unwrap(), archive.header, "{name}");

    let expected = expected_field();
    let coord = cpu_coordinator();
    let out = coord
        .decompress(&archive)
        .unwrap_or_else(|e| panic!("{name}: no longer decodes: {e:#}"));
    assert_eq!(out.dims, vec![65536], "{name}");
    // byte-for-byte: legacy payloads must reconstruct the exact field
    // they always did, not merely something within the bound
    let out_bits: Vec<u32> = out.data.iter().map(|v| v.to_bits()).collect();
    let exp_bits: Vec<u32> = expected.iter().map(|v| v.to_bits()).collect();
    assert_eq!(out_bits, exp_bits, "{name}: decoded field drifted");
    // and the recorded error bound holds against the committed original
    assert_eq!(
        metrics::verify_error_bound(&expected, &out.data, archive.header.abs_eb),
        None,
        "{name}"
    );

    if expect_byte_stable {
        // uncompressed legacy payloads must also re-serialize unchanged
        // (their on-disk digests — e.g. store payload CRCs — depend on it)
        assert_eq!(archive.to_bytes(), bytes, "{name}: re-serialization drifted");
    }
    archive
}

#[test]
fn v0_huffman_fixture_decodes() {
    let a = check_fixture(
        "v0_huffman_none.cusza",
        0,
        EncoderKind::Huffman,
        CodecGranularity::Field,
        true,
    );
    assert_eq!(a.header.field_name, "fixture/v0-huffman");
    assert_eq!(a.header.eb, ErrorBound::Abs(0.03125));
    assert_eq!(a.outliers.len(), 34);
    assert_eq!(a.verbatim.len(), 3);
}

#[test]
fn v1_huffman_gzip_fixture_decodes() {
    // gzip bodies are not byte-stable across deflate implementations, so
    // only the decode direction is pinned for this one
    let a = check_fixture(
        "v1_huffman_gzip.cusza",
        1,
        EncoderKind::Huffman,
        CodecGranularity::Field,
        false,
    );
    assert_eq!(a.header.field_name, "fixture/v1-huffman-gzip");
    assert_eq!(a.header.eb, ErrorBound::ValRel(1e-3));
}

#[test]
fn v1_fle_fixture_decodes() {
    let a =
        check_fixture("v1_fle_none.cusza", 1, EncoderKind::Fle, CodecGranularity::Field, true);
    assert_eq!(a.header.field_name, "fixture/v1-fle");
    // FLE sidecar: one width byte per chunk
    assert_eq!(a.encoder_aux.len(), a.stream.chunks.len());
}

#[test]
fn v3_fle_fixture_decodes_and_is_byte_stable() {
    // the current generation, uncompressed: parse + decode + re-serialize
    // byte-for-byte (store payload CRCs depend on the re-serialization)
    let a =
        check_fixture("v3_fle_none.cusza", 3, EncoderKind::Fle, CodecGranularity::Field, true);
    assert_eq!(a.header.field_name, "fixture/v3-fle");
    assert_eq!(a.encoder_aux.len(), a.stream.chunks.len());
}

#[test]
fn v3_segmented_gzip_fixture_decodes() {
    // the zero-copy encode path's segmented lossless tail: the fixture
    // carries a real multi-segment table (16 KiB segments over an ~84 KB
    // body) and must keep decoding even if the writer's segment sizing
    // changes — segmentation is a writer property, readers accept any
    let a = check_fixture(
        "v3_huffman_gzipseg.cusza",
        3,
        EncoderKind::Huffman,
        CodecGranularity::Field,
        false,
    );
    assert_eq!(a.header.field_name, "fixture/v3-huffman-gzipseg");
}

#[test]
fn v3_mixed_granularity_segmented_fixture_decodes() {
    // chunk granularity (huffman/FLE tag table) under a segmented tail
    let a = check_fixture(
        "v3_mixed_gzipseg.cusza",
        3,
        EncoderKind::Huffman,
        CodecGranularity::Chunk,
        false,
    );
    assert_eq!(a.header.field_name, "fixture/v3-mixed-gzipseg");
    assert_eq!(a.chunk_tags.len(), a.stream.chunks.len());
    assert!(a.chunk_tags.contains(&EncoderKind::Huffman.to_tag()));
    assert!(a.chunk_tags.contains(&EncoderKind::Fle.to_tag()));
}

#[test]
fn v4_huffman_gap_fixture_decodes_and_is_byte_stable() {
    // format version 4: per-chunk gap tables under larger 16384-symbol
    // chunks. No lossless tail, so the byte-stability check locks the
    // current writer's gap-section framing against the python mirror
    // that built the fixture — and the decode exercises the subchunk-
    // parallel gap path end to end.
    let a = check_fixture(
        "v4_huffman_gap.cusza",
        4,
        EncoderKind::Huffman,
        CodecGranularity::Field,
        true,
    );
    assert_eq!(a.header.field_name, "fixture/v4-huffman-gap");
    assert_eq!(a.header.chunk_symbols, 16384);
    assert_eq!(a.gap_tables.len(), a.stream.chunks.len());
    for (gt, chunk) in a.gap_tables.iter().zip(&a.stream.chunks) {
        assert_eq!(gt.len(), 4, "16384-symbol chunk = four 4096-symbol subchunks");
        assert_eq!(gt[0], (0, 4096));
        assert_eq!(gt.iter().map(|&(_, c)| c as u64).sum::<u64>(), chunk.symbols as u64);
    }
    // stripping the sidecar must decode to the same bits (serial path)
    let mut serial = a.clone();
    serial.gap_tables = Vec::new();
    let coord = cpu_coordinator();
    let gap_out = coord.decompress(&a).unwrap();
    let ser_out = coord.decompress(&serial).unwrap();
    let bits = |d: &[f32]| d.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&gap_out.data), bits(&ser_out.data));
}

#[test]
fn all_fixture_archives_decode_to_the_same_field() {
    // seven encodings of one field: their symbol streams must agree
    let coord = cpu_coordinator();
    let mut decoded = Vec::new();
    for name in [
        "v0_huffman_none.cusza",
        "v1_huffman_gzip.cusza",
        "v1_fle_none.cusza",
        "v3_fle_none.cusza",
        "v3_huffman_gzipseg.cusza",
        "v3_mixed_gzipseg.cusza",
        "v4_huffman_gap.cusza",
    ] {
        let archive = Archive::from_bytes(&std::fs::read(fixture_path(name)).unwrap()).unwrap();
        decoded.push(coord.decompress(&archive).unwrap().data);
    }
    let bits = |v: &Vec<f32>| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    for other in &decoded[1..] {
        assert_eq!(bits(&decoded[0]), bits(other));
    }
}

#[test]
fn legacy_bundle_opens_and_decodes() {
    let store = Store::open(fixture_path("bundle_v1.cuszb")).unwrap();
    assert_eq!(store.len(), 2);
    store.verify().unwrap();
    let expected = expected_field();
    let coord = cpu_coordinator();
    for name in ["fixture/v0-huffman", "fixture/v1-fle"] {
        let archive = store.get(name).unwrap();
        let out = coord.decompress(&archive).unwrap();
        assert_eq!(
            metrics::verify_error_bound(&expected, &out.data, archive.header.abs_eb),
            None,
            "{name}"
        );
        let out_bits: Vec<u32> = out.data.iter().map(|v| v.to_bits()).collect();
        let exp_bits: Vec<u32> = expected.iter().map(|v| v.to_bits()).collect();
        assert_eq!(out_bits, exp_bits, "{name}");
    }
}

#[test]
fn current_writer_emits_cusza4_while_fixtures_stay_readable() {
    // one coordinator handles both generations: fresh archives carry the
    // new magic, fixtures keep decoding beside them
    let coord = cpu_coordinator();
    let expected = expected_field();
    let field = cusz::field::Field::new("fresh", vec![65536], expected).unwrap();
    let fresh = coord.compress(&field).unwrap();
    let bytes = fresh.to_bytes();
    assert_eq!(&bytes[..8], cusz::container::MAGIC);
    assert_eq!(fresh.header.version, cusz::container::FORMAT_VERSION);
    let old = Archive::from_bytes(&std::fs::read(fixture_path("v0_huffman_none.cusza")).unwrap())
        .unwrap();
    coord.decompress(&old).unwrap();
    coord.decompress(&Archive::from_bytes(&bytes).unwrap()).unwrap();
}
