//! Integration: the PJRT path (AOT HLO executables) must be **bit-exact**
//! with the pure-Rust CPU mirror on every variant — the L1↔L3 contract.
//!
//! Requires `make artifacts`; tests skip (with a note) when absent.

use std::path::PathBuf;

use cusz::runtime::{ArtifactManifest, CpuEngine, QuantEngine};
use cusz::util::prng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.tsv").exists().then_some(dir)
}

fn field_for(spec: &cusz::sz::blocks::SlabSpec, seed: u64, style: &str) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let n = spec.len();
    match style {
        "smooth" => {
            let mut acc = 0f32;
            (0..n)
                .map(|_| {
                    acc += rng.normal() * 0.02;
                    acc
                })
                .collect()
        }
        "zeros" => (0..n)
            .map(|_| if rng.f32() < 0.03 { rng.normal() * 100.0 } else { 0.0 })
            .collect(),
        _ => (0..n).map(|_| rng.normal() * 10.0).collect(),
    }
}

#[test]
fn pjrt_matches_cpu_bit_exact_all_variants() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let pjrt = cusz::runtime::pjrt::PjrtEngine::start(manifest.clone()).unwrap();
    let cpu = CpuEngine { dict_size: manifest.dict_size() };

    for meta in manifest.executables.iter().filter(|e| e.op == "compress") {
        let spec = meta.slab_spec();
        if spec.len() > 1 << 20 {
            continue; // keep CI time bounded; big slabs covered by 1d_1m
        }
        for (i, style) in ["smooth", "noisy", "zeros"].iter().enumerate() {
            let data = field_for(&spec, 1000 + i as u64, style);
            let eb = 1e-3f32;
            let d_pjrt = pjrt.compress_slab(&spec, &data, eb).unwrap();
            let d_cpu = cpu.compress_slab(&spec, &data, eb).unwrap();
            assert_eq!(d_pjrt, d_cpu, "delta mismatch {} {style}", meta.variant);

            let r_pjrt = pjrt.decompress_slab(&spec, &d_pjrt, eb).unwrap();
            let r_cpu = cpu.decompress_slab(&spec, &d_cpu, eb).unwrap();
            assert_eq!(r_pjrt, r_cpu, "recon mismatch {} {style}", meta.variant);

            // and the reconstruction honors the bound
            assert_eq!(
                cusz::metrics::verify_error_bound(&data, &r_pjrt, eb),
                None,
                "error bound violated on {} {style}",
                meta.variant
            );
        }
    }
}

#[test]
fn pjrt_device_histogram_matches_cpu() {
    // The paper's §3.2.1 device histogram kernel, exported standalone.
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let meta = manifest.find("histogram", "2d_256").unwrap().clone();
    let spec = meta.slab_spec();
    let dict = manifest.dict_size();
    let pjrt = cusz::runtime::pjrt::PjrtEngine::start(manifest).unwrap();
    let cpu = CpuEngine { dict_size: dict };
    let mut rng = Rng::new(77);
    let codes: Vec<i32> = (0..spec.len()).map(|_| rng.below(dict as u64) as i32).collect();
    let h_dev = pjrt.device_histogram(&spec, &codes, dict).unwrap();
    let h_cpu = cpu.device_histogram(&spec, &codes, dict).unwrap();
    assert_eq!(h_dev, h_cpu);
    assert_eq!(h_dev.iter().map(|&h| h as usize).sum::<usize>(), spec.len());
}
