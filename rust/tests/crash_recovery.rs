//! Crashpoint-injection harness: for every registered crashpoint
//! (`cusz::store::crashpoints::ALL`), run the covering store mutation in
//! a child process with `CUSZ_CRASHPOINT` armed, let the child `abort()`
//! at the point, then prove recovery from the wreckage:
//!
//! - `cusz store fsck --repair --quarantine` converges (exit 0, and a
//!   second scan is clean);
//! - the store reopens writable and fully verifies;
//! - every write the driver had durably acked *before* the crash is
//!   still present and bit-identical;
//! - no torn swap state (staging / graveyard / swap-intent marker) and
//!   no stale machinery files survive.
//!
//! The child is this same test binary re-invoked with `--exact
//! crash_child`; the `crash_child` test is a no-op unless `CUSZ_CRASH_OP`
//! is set, so it is invisible to a normal `cargo test` run.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::process::Command;

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::field::Field;
use cusz::store::fsck::{fsck, scan};
use cusz::store::{crashpoints, Durability, FsckOptions, Store};
use cusz::testkit::fields::{make, Regime};
use cusz::testkit::tmp_dir;

/// Which store mutation the child performs (driver -> child).
const OP_ENV: &str = "CUSZ_CRASH_OP";
/// The bundle directory the child operates on (driver -> child).
const DIR_ENV: &str = "CUSZ_CRASH_DIR";
/// Printed by the child only if its op ran to completion — i.e. the
/// armed crashpoint never fired, which the driver treats as a harness
/// bug (a registered point its op does not reach).
const DONE: &str = "CRASH-CHILD-COMPLETED";

fn coordinator() -> Coordinator {
    Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(1e-3),
        threads: 1,
        ..Default::default()
    })
    .unwrap()
}

fn payload_for(name: &str, seed: u64) -> Vec<u8> {
    let f = Field::new(
        name.to_string(),
        vec![32, 32],
        make(Regime::ALL[(seed % 3) as usize], 32 * 32, seed),
    )
    .unwrap();
    coordinator().compress_encoded(&f).unwrap().bytes
}

/// Build a fresh seed bundle for one crash run. Returns the exact payload
/// bytes of every durably-acked field — the driver's ground truth for the
/// post-crash bit-identity audit. `f_bad` (quarantine op only) is
/// deliberately corrupted after its ack and excluded from the map.
fn seed_store(tag: &str, op: &str) -> (PathBuf, BTreeMap<String, Vec<u8>>) {
    let dir = tmp_dir(tag);
    let mut store = Store::create(&dir, 2).unwrap();
    store.set_durability(Durability::Sync);
    let mut kept = BTreeMap::new();
    for i in 0..3u64 {
        let name = format!("f{i}");
        let payload = payload_for(&name, i);
        store.add_bytes(&name, &payload).unwrap();
        kept.insert(name, payload);
    }
    match op {
        "compact" => {
            // re-put f1 so the bundle carries dead bytes worth compacting
            let p = kept["f1"].clone();
            store.put_bytes("f1", &p).unwrap();
            assert!(store.dead_bytes() > 0);
        }
        "quarantine" => {
            let p = payload_for("f_bad", 9);
            let e = store.put_bytes("f_bad", &p).unwrap();
            drop(store);
            // flip a payload byte so the child has a real corruption to move
            let path = dir.join(format!("shard-{:04}.cuszs", e.shard));
            let mut bytes = std::fs::read(&path).unwrap();
            bytes[(e.offset + e.len / 2) as usize] ^= 0xff;
            std::fs::write(&path, &bytes).unwrap();
            return (dir, kept);
        }
        _ => {}
    }
    drop(store);
    (dir, kept)
}

/// The mutation that covers a crashpoint's namespace. `index.*` points
/// fire inside every index publish; the append op reaches them.
fn op_for(point: &str) -> &'static str {
    for (prefix, op) in [
        ("append.", "append"),
        ("index.", "append"),
        ("remove.", "remove"),
        ("compact.", "compact"),
        ("quarantine.", "quarantine"),
    ] {
        if point.starts_with(prefix) {
            return op;
        }
    }
    panic!("crashpoint '{point}' has no covering op — extend op_for()");
}

/// Child half of the harness: performs one store mutation under
/// `Durability::Sync` with a crashpoint armed via the environment, and
/// dies mid-operation when execution reaches it.
#[test]
fn crash_child() {
    let Ok(op) = std::env::var(OP_ENV) else {
        return; // normal test run: nothing to do
    };
    let dir = PathBuf::from(std::env::var(DIR_ENV).expect("CUSZ_CRASH_DIR not set"));
    let mut store = Store::open_writable(&dir).expect("child: open store");
    store.set_durability(Durability::Sync);
    match op.as_str() {
        "append" => {
            let payload = payload_for("crashme", 7);
            store.put_bytes("crashme", &payload).expect("child: put");
        }
        "remove" => {
            store.remove("f0").expect("child: remove");
        }
        "compact" => {
            store.compact_in_place().expect("child: compact");
        }
        "quarantine" => {
            store.quarantine("f_bad", "harness-injected corruption").expect("child: quarantine");
        }
        other => panic!("child: unknown crash op '{other}'"),
    }
    println!("{DONE}");
}

#[test]
fn every_crashpoint_recovers_without_losing_acked_writes() {
    let exe = std::env::current_exe().expect("test binary path");
    for &point in crashpoints::ALL {
        let op = op_for(point);
        let tag = format!("crash-{}", point.replace('.', "-"));
        let (dir, kept) = seed_store(&tag, op);

        let out = Command::new(&exe)
            .args(["crash_child", "--exact", "--nocapture", "--test-threads=1"])
            .env(crashpoints::ENV, point)
            .env(OP_ENV, op)
            .env(DIR_ENV, &dir)
            .output()
            .expect("spawning crash child");
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(
            !out.status.success(),
            "{point}: child exited cleanly instead of aborting\n{stdout}"
        );
        assert!(
            !stdout.contains(DONE),
            "{point}: the armed crashpoint never fired — '{op}' ran to completion\n{stdout}"
        );

        // recovery: repair converges, and a second scan finds nothing
        let report = fsck(&dir, &FsckOptions { repair: true, quarantine: true })
            .unwrap_or_else(|e| panic!("{point}: fsck errored: {e:#}"));
        assert_eq!(report.exit_code(), 0, "{point}: repair left findings:\n{}", report.render());
        let rescan = scan(&dir).unwrap_or_else(|e| panic!("{point}: rescan errored: {e:#}"));
        assert!(rescan.clean(), "{point}: repair did not converge:\n{}", rescan.render());

        // the store reopens writable (its own reconciliation path) and
        // every durably-acked write survived, bit for bit
        let store = Store::open_writable(&dir)
            .unwrap_or_else(|e| panic!("{point}: reopen failed: {e:#}"));
        store.verify().unwrap_or_else(|e| panic!("{point}: verify failed: {e:#}"));
        for (name, payload) in &kept {
            assert!(store.contains(name), "{point}: acked field '{name}' lost");
            let got = store
                .get_bytes(name)
                .unwrap_or_else(|e| panic!("{point}: reading acked '{name}': {e:#}"));
            assert_eq!(&got, payload, "{point}: acked field '{name}' not bit-identical");
        }
        drop(store);

        // no torn swap state outlives recovery
        let parent = dir.parent().unwrap().to_path_buf();
        let base = dir.file_name().unwrap().to_string_lossy().into_owned();
        for suffix in ["compact-tmp", "old-tmp", "swap-intent"] {
            let p = parent.join(format!("{base}.{suffix}"));
            assert!(!p.exists(), "{point}: leftover swap state {}", p.display());
        }
        // ... and no stale machinery inside the bundle (the writer lock
        // itself was released when the store handle dropped above)
        for entry in std::fs::read_dir(&dir).unwrap() {
            let n = entry.unwrap().file_name().to_string_lossy().into_owned();
            assert!(
                !n.ends_with(".tmp") && !n.starts_with(".writer.lock."),
                "{point}: stale artifact '{n}' survived recovery"
            );
            assert_ne!(n, "writer.lock", "{point}: writer lock leaked");
        }

        std::fs::remove_dir_all(&dir).unwrap();
    }
}
