//! Encoder-matrix integration tests: every codec pipeline variant —
//! encoder (huffman/fle/rle) × lossless tail (none/gzip/zstd) ×
//! dimensionality (1D/2D/3D) × data regime — must roundtrip through
//! archive bytes within the error bound. Plus the auto-mode selection
//! shape (field and chunk granularity) and version-0 archive
//! compatibility at the coordinator level.

use cusz::codec::{CodecGranularity, CodecSpec, EncoderChoice, EncoderKind};
use cusz::config::{BackendKind, CuszConfig, ErrorBound, LosslessStage};
use cusz::container::Archive;
use cusz::coordinator::Coordinator;
use cusz::field::Field;
use cusz::metrics;
use cusz::testkit::fields::{make, Regime};
use cusz::util::prng::Rng;

const EB: f32 = 1e-3;

fn coordinator(codec: CodecSpec) -> Coordinator {
    Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(EB as f64),
        codec,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn encoder_matrix_roundtrips_within_bound() {
    let encoders = [EncoderChoice::Huffman, EncoderChoice::Fle, EncoderChoice::Rle];
    let stages = [LosslessStage::None, LosslessStage::Gzip, LosslessStage::Zstd];
    let shapes: [&[usize]; 3] = [&[20_000], &[120, 160], &[24, 30, 28]];
    for &encoder in &encoders {
        for &lossless in &stages {
            let coord = coordinator(CodecSpec { encoder, lossless, ..Default::default() });
            for (si, &shape) in shapes.iter().enumerate() {
                for (ri, regime) in Regime::ALL.into_iter().enumerate() {
                    let n: usize = shape.iter().product();
                    let seed = (si * 3 + ri) as u64 + 1;
                    let field =
                        Field::new("m", shape.to_vec(), make(regime, n, seed)).unwrap();
                    let (archive, stats) = coord.compress_with_stats(&field).unwrap();
                    let expect = match encoder {
                        EncoderChoice::Huffman => EncoderKind::Huffman,
                        EncoderChoice::Fle => EncoderKind::Fle,
                        EncoderChoice::Rle => EncoderKind::Rle,
                        EncoderChoice::Auto => unreachable!(),
                    };
                    assert_eq!(archive.header.encoder, expect);
                    assert_eq!(stats.encoder, expect);
                    // through serialized bytes, like the store path
                    let restored = Archive::from_bytes(&archive.to_bytes()).unwrap();
                    let out = coord.decompress(&restored).unwrap();
                    assert_eq!(out.dims, field.dims);
                    assert_eq!(
                        metrics::verify_error_bound(&field.data, &out.data, EB),
                        None,
                        "{encoder:?} {lossless:?} {shape:?} {regime:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn auto_mode_adapts_to_smoothness() {
    let auto = |lossless| CodecSpec { encoder: EncoderChoice::Auto, lossless, ..Default::default() };

    // smooth random walk, comfortable bound: deltas land in a handful of
    // bins around the radius -> skewed histogram -> Huffman
    let coord = Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(1e-2),
        codec: auto(LosslessStage::None),
        ..Default::default()
    })
    .unwrap();
    let smooth = Field::new("s", vec![50_000], make(Regime::Smooth, 50_000, 2)).unwrap();
    let (archive, _) = coord.compress_with_stats(&smooth).unwrap();
    assert_eq!(archive.header.encoder, EncoderKind::Huffman, "smooth -> huffman");
    let out = coord.decompress(&archive).unwrap();
    assert_eq!(metrics::verify_error_bound(&smooth.data, &out.data, 1e-2), None);

    // white noise scaled so prediction deltas spread over ~±125 bins:
    // entropy approaches the fixed width -> FLE
    let mut rng = Rng::new(77);
    let noisy: Vec<f32> = (0..50_000).map(|_| rng.f32() * 0.25).collect();
    let field = Field::new("n", vec![50_000], noisy).unwrap();
    let coord = coordinator(auto(LosslessStage::None));
    let (archive, stats) = coord.compress_with_stats(&field).unwrap();
    assert_eq!(archive.header.encoder, EncoderKind::Fle, "noisy -> fle");
    assert_eq!(stats.encoder, EncoderKind::Fle);
    let out = coord.decompress(&archive).unwrap();
    assert_eq!(metrics::verify_error_bound(&field.data, &out.data, EB), None);
}

#[test]
fn fle_with_lossless_tail_beats_raw_fle_on_shuffled_planes() {
    // the point of the bitplane shuffle: the lossless tail sees long
    // near-constant runs, so zstd over FLE output must shrink it
    let field = Field::new("z", vec![64, 256], make(Regime::Smooth, 64 * 256, 5)).unwrap();
    let raw = coordinator(CodecSpec { encoder: EncoderChoice::Fle, lossless: LosslessStage::None, ..Default::default() })
        .compress(&field)
        .unwrap();
    let zstd = coordinator(CodecSpec { encoder: EncoderChoice::Fle, lossless: LosslessStage::Zstd, ..Default::default() })
        .compress(&field)
        .unwrap();
    assert!(
        zstd.compressed_bytes() < raw.compressed_bytes(),
        "zstd tail should shrink shuffled planes: {} vs {}",
        zstd.compressed_bytes(),
        raw.compressed_bytes()
    );
}

/// A field that interleaves smoothness regimes in large stripes, so the
/// slab-major symbol stream alternates between constant, gaussian, and
/// wide-noise chunks — the workload per-chunk selection exists for.
fn mixed_regime_field(n: usize, seed: u64) -> Field {
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n);
    let mut acc = 0f32;
    for i in 0..n {
        match (i / 8192) % 3 {
            0 => data.push(0.0),
            1 => {
                acc += rng.normal() * 0.002;
                data.push(acc);
            }
            _ => data.push(rng.normal() * 0.5),
        }
    }
    Field::new("mixed", vec![n], data).unwrap()
}

#[test]
fn per_chunk_auto_beats_every_uniform_encoder_on_mixed_fields() {
    let n = 1 << 17; // two 1d_64k slabs, 32 chunks
    let field = mixed_regime_field(n, 3);
    let uniform_best = [EncoderChoice::Huffman, EncoderChoice::Fle, EncoderChoice::Rle]
        .into_iter()
        .map(|encoder| {
            coordinator(CodecSpec { encoder, ..Default::default() })
                .compress(&field)
                .unwrap()
                .compressed_bytes()
        })
        .min()
        .unwrap();
    let chunked = coordinator(CodecSpec {
        encoder: EncoderChoice::Auto,
        granularity: CodecGranularity::Chunk,
        ..Default::default()
    });
    let (archive, stats) = chunked.compress_with_stats(&field).unwrap();
    assert_eq!(archive.header.granularity, CodecGranularity::Chunk);
    assert_eq!(archive.chunk_tags.len(), archive.stream.chunks.len());
    // the win condition: per-chunk selection is at least as small as the
    // best single-backend choice (within the tag table's own overhead)
    // tag table + shared codebook + per-chunk sidecar records + framing
    let overhead = 4 * archive.chunk_tags.len() + archive.encoder_aux.len() + 128;
    assert!(
        archive.compressed_bytes() <= uniform_best + overhead,
        "per-chunk {} vs best uniform {}",
        archive.compressed_bytes(),
        uniform_best
    );
    // stripes actually split across backends
    let used = stats.chunk_counts.iter().filter(|&&c| c > 0).count();
    assert!(used >= 2, "chunk counts {:?}", stats.chunk_counts);
    // and the mixed archive roundtrips through bytes
    let restored = Archive::from_bytes(&archive.to_bytes()).unwrap();
    let out = chunked.decompress(&restored).unwrap();
    assert_eq!(metrics::verify_error_bound(&field.data, &out.data, EB), None);
}

#[test]
fn mixed_archive_decodes_on_any_coordinator_and_through_store() {
    use cusz::store::Store;
    use cusz::testkit::tmp_dir;

    let field = mixed_regime_field(1 << 16, 9);
    let chunked = coordinator(CodecSpec {
        encoder: EncoderChoice::Auto,
        granularity: CodecGranularity::Chunk,
        ..Default::default()
    });
    let archive = chunked.compress(&field).unwrap();
    assert!(!archive.chunk_tags.is_empty());

    // a default (huffman/field) coordinator decodes it: the tag table,
    // not the config, picks the stages
    let plain = coordinator(CodecSpec::default());
    let out = plain.decompress(&archive).unwrap();
    assert_eq!(metrics::verify_error_bound(&field.data, &out.data, EB), None);

    // and it survives the store path byte-identically
    let dir = tmp_dir("codec-mixed-store");
    let mut store = Store::create(&dir, 1).unwrap();
    store.add(&archive).unwrap();
    let restored = store.get("mixed").unwrap();
    assert_eq!(restored, archive);
    let out = plain.decompress(&restored).unwrap();
    assert_eq!(metrics::verify_error_bound(&field.data, &out.data, EB), None);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn v0_payload_decodes_through_store_path() {
    use cusz::store::Store;
    use cusz::testkit::tmp_dir;

    // a pre-refactor payload: Huffman, version-0 header, legacy magic
    let field = Field::new("old", vec![96, 96], make(Regime::Smooth, 96 * 96, 8)).unwrap();
    let coord = coordinator(CodecSpec::default());
    let mut archive = coord.compress(&field).unwrap();
    archive.header.version = 0;
    let v0_bytes = archive.to_bytes();

    let dir = tmp_dir("codec-v0-store");
    let mut store = Store::create(&dir, 1).unwrap();
    store.add_bytes("old", &v0_bytes).unwrap();
    drop(store);
    let store = Store::open(&dir).unwrap();
    let restored = store.get("old").unwrap();
    assert_eq!(restored.header.version, 0);
    assert_eq!(restored.header.encoder, EncoderKind::Huffman);
    let out = coord.decompress(&restored).unwrap();
    assert_eq!(metrics::verify_error_bound(&field.data, &out.data, EB), None);
    std::fs::remove_dir_all(&dir).unwrap();
}
