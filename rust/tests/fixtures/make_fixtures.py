#!/usr/bin/env python3
"""Golden-fixture generator for the archive-format compatibility corpus.

Emits byte-exact legacy archives (CUSZA1 = format version 0, CUSZA2 =
format version 1), CUSZA3 archives (format version 3: granularity byte,
optional per-chunk tag table, and the segmented gzip lossless tail
introduced by the zero-copy encode path), a current-generation CUSZA4
archive (format version 4: per-chunk Huffman gap tables for subchunk-
parallel decode), plus a `.cuszb` bundle, together with the exact f32
field every archive decodes to. `tests/format_compat.rs` decodes every fixture with the current code
and compares byte-for-byte — so a format bump that would orphan old (or
current) payloads fails CI instead of shipping.

The payloads are built from first principles (bit-level mirrors of the
canonical-Huffman and FLE chunk codecs, the container framing, and the
store index), not by running an old binary: the fixture field is chosen
so the decode path — per-block prefix sums of the quant deltas times
2·eb — is exact in f32 arithmetic, which makes the expected output
reproducible from this script alone.

Regenerate with:  python3 rust/tests/fixtures/make_fixtures.py
(The committed binaries are canonical; regeneration must be a no-op.)
"""

import gzip
import os
import struct
import zlib

HERE = os.path.dirname(os.path.abspath(__file__))
MASK64 = (1 << 64) - 1

N = 65536            # dims [65536] -> one 1d_64k slab, no padding
DICT = 1024
RADIUS = 512
CHUNK = 4096         # 16 chunks
ABS_EB = 0.03125     # 2*eb = 0.0625 = 2^-4: exact f32 scaling


# ---------- bit-level mirror of util/bitio.rs (LSB-first) ----------

class BitWriter:
    def __init__(self):
        self.words, self.acc, self.fill, self.len_bits = [], 0, 0, 0

    def write(self, value, n):
        if n == 0:
            return
        value &= (1 << n) - 1
        self.acc = (self.acc | (value << self.fill)) & MASK64
        used = 64 - self.fill
        if n >= used:
            self.words.append(self.acc)
            self.acc = 0 if used == 64 else (value >> used)
            self.fill = n - used
        else:
            self.fill += n
        self.len_bits += n

    def finish(self):
        if self.fill > 0:
            self.words.append(self.acc)
        return self.words, self.len_bits


def rev_bits(v, n):
    out = 0
    for _ in range(n):
        out = (out << 1) | (v & 1)
        v >>= 1
    return out


# ---------- the fixture field: quant codes + side channels ----------

def lcg_stream(seed):
    state = seed
    while True:
        state = (state * 6364136223846793005 + 1442695040888963407) & MASK64
        yield (state >> 33) & 0x7FFFFFFF


def build_codes():
    rng = lcg_stream(2020)
    codes = []
    for i in range(N):
        if i % 977 == 0:
            codes.append(0)  # outlier marker
        elif i < 20000:
            codes.append(512 + (i % 7) - 3)
        elif i < 40000:
            codes.append(512)  # constant stretch
        else:
            codes.append(512 + (next(rng) % 31) - 15)
    return codes


def build_side_channels():
    # exact deltas for every other marker slot (the rest decode as 0)
    outliers = []
    for i in range(0, N, 977):
        if (i // 977) % 2 == 0:
            outliers.append((i, 1500 - (i % 3001)))
    verbatim = [(100, 3.5), (33333, -1.25e30), (65000, 0.015625)]
    return outliers, verbatim


def expected_field(codes, outliers, verbatim):
    deltas = [c - RADIUS if c != 0 else 0 for c in codes]
    for pos, d in outliers:
        deltas[pos] = d
    out = []
    for b in range(0, N, 32):  # 1D lorenzo inverse: prefix sum per block
        acc = 0
        for i in range(32):
            acc += deltas[b + i]
            assert abs(acc) < (1 << 20)
            out.append(acc * (2.0 * ABS_EB))
    raw = bytearray()
    for v in out:
        raw += struct.pack("<f", v)
    for pos, v in verbatim:
        raw[pos * 4:pos * 4 + 4] = struct.pack("<f", v)
    return bytes(raw)


# ---------- symbol encoders (mirrors of the Rust chunk codecs) ----------

def huffman_chunks(codes, chunk=CHUNK):
    """All-1024-symbols-at-length-10 canonical codebook: codeword of
    symbol s is s itself, emitted bit-reversed LSB-first (codebook.rs)."""
    chunks = []
    for lo in range(0, N, chunk):
        w = BitWriter()
        seg = codes[lo:lo + chunk]
        for s in seg:
            w.write(rev_bits(s, 10), 10)
        words, bits = w.finish()
        chunks.append((words, bits, len(seg)))
    return bytes([10] * DICT), chunks


GAP_SUBCHUNK = 4096  # mirror of huffman::GAP_SUBCHUNK


def gap_tables_for(chunks):
    """Mirror of deflate_one_gap's sidecar under the all-length-10
    codebook: one (bit offset, symbol count) entry per 4096-symbol
    subchunk; chunks at or under the granularity carry no table."""
    tables = []
    for _words, _bits, symbols in chunks:
        if symbols <= GAP_SUBCHUNK:
            tables.append([])
            continue
        table = []
        for lo in range(0, symbols, GAP_SUBCHUNK):
            n = min(GAP_SUBCHUNK, symbols - lo)
            table.append((lo * 10, n))  # every codeword is 10 bits
        tables.append(table)
    return tables


def transform(s):
    if s == 0:
        return 0
    d = s - RADIUS
    z = (d << 1) if d >= 0 else ((-d << 1) - 1)
    return z + 1


def fle_chunks(codes):
    aux = bytearray()
    chunks = []
    for lo in range(0, N, CHUNK):
        seg = codes[lo:lo + CHUNK]
        ngroups = (len(seg) + 63) // 64
        planes = [[0] * 17 for _ in range(ngroups)]
        allv = 0
        for g in range(ngroups):
            for i, s in enumerate(seg[g * 64:(g + 1) * 64]):
                v = transform(s)
                allv |= v
                while v:
                    b = (v & -v).bit_length() - 1
                    planes[g][b] |= 1 << i
                    v &= v - 1
        wbits = allv.bit_length()
        w = BitWriter()
        rem = len(seg)
        for p in planes:
            gl = min(rem, 64)
            for b in range(wbits):
                w.write(p[b], gl)
            rem -= gl
        words, bits = w.finish()
        assert bits == len(seg) * wbits
        aux.append(wbits)
        chunks.append((words, bits, len(seg)))
    return bytes(aux), chunks


# ---------- container framing (mirror of container/{bytes,header,mod}.rs) ----------

def section(payload):
    return struct.pack("<QI", len(payload), zlib.crc32(payload) & 0xFFFFFFFF) + payload


def pstr(s):
    b = s.encode()
    return struct.pack("<I", len(b)) + b


def header_bytes(version, encoder_tag, name, eb_mode, eb_value, repr_bits, lossless_tag,
                 granularity=0, chunk_symbols=CHUNK):
    h = b""
    if version >= 1:
        h += struct.pack("<BB", version, encoder_tag)
    if version >= 2:
        h += struct.pack("<B", granularity)
    h += pstr(name)
    h += struct.pack("<I", 1) + struct.pack("<Q", N)      # dims
    h += pstr("1d_64k")                                    # variant
    h += struct.pack("<B", eb_mode) + struct.pack("<d", eb_value)
    h += struct.pack("<f", ABS_EB)
    h += struct.pack("<III", DICT, chunk_symbols, repr_bits)
    h += struct.pack("<B", lossless_tag)
    h += struct.pack("<Q", 1)                              # n_slabs
    return h


def body_bytes(aux, chunks, outliers, verbatim, version=1, chunk_tags=None, chunk_aux=None,
               chunk_symbols=CHUNK, gap_tables=None):
    b = struct.pack("<I", len(aux)) + aux
    b += struct.pack("<II", len(chunks), chunk_symbols)
    for words, bits, symbols in chunks:
        b += struct.pack("<QII", bits, symbols, len(words))
        for w in words:
            b += struct.pack("<Q", w)
    if version >= 2:
        tags = bytes(chunk_tags or [])
        b += struct.pack("<I", len(tags)) + tags
        if tags:
            for rec in chunk_aux:
                b += struct.pack("<B", len(rec)) + bytes(rec)
    if version >= 4:
        gts = gap_tables or []
        b += struct.pack("<I", len(gts))
        for gt in gts:
            b += struct.pack("<I", len(gt))
            for off, cnt in gt:
                b += struct.pack("<QI", off, cnt)
    b += struct.pack("<Q", len(outliers))
    for pos, d in outliers:
        b += struct.pack("<Qi", pos, d)
    b += struct.pack("<Q", len(verbatim))
    for pos, v in verbatim:
        b += struct.pack("<Qf", pos, v)
    return b


def segmented_gzip_tail(body, seg_bytes):
    """Mirror of container::encode_segmented_tail (format version 3):
    [u64 raw_total][u32 n_segments] + per-segment [u64 raw][u64 comp]
    table + concatenated gzip payloads."""
    nsegs = max(1, -(-len(body) // seg_bytes))
    parts = [gzip.compress(body[i * seg_bytes:(i + 1) * seg_bytes], mtime=0)
             for i in range(nsegs)]
    out = struct.pack("<QI", len(body), nsegs)
    for i, p in enumerate(parts):
        raw = min((i + 1) * seg_bytes, len(body)) - i * seg_bytes
        out += struct.pack("<QQ", raw, len(p))
    return out + b"".join(parts)


def archive_bytes(magic, header, body, gzip_body=False, gzip_seg_bytes=None):
    if gzip_seg_bytes is not None:
        body = segmented_gzip_tail(body, gzip_seg_bytes)
    elif gzip_body:
        body = gzip.compress(body, mtime=0)
    return magic + section(header) + section(body)


# ---------- .cuszb bundle (mirror of store/{index,mod}.rs) ----------

def bundle(dirname, entries):
    os.makedirs(dirname, exist_ok=True)
    shard = b"CUSZS1\x00\x00"
    index_entries = []
    for name, payload, header in entries:
        offset = len(shard)
        shard += payload
        index_entries.append((name, 0, offset, len(payload),
                              zlib.crc32(payload) & 0xFFFFFFFF,
                              zlib.crc32(header) & 0xFFFFFFFF))
    with open(os.path.join(dirname, "shard-0000.cuszs"), "wb") as f:
        f.write(shard)
    body = struct.pack("<IQ", 1, len(index_entries))
    for name, sh, off, ln, pcrc, hcrc in index_entries:
        body += pstr(name)
        body += struct.pack("<IQQII", sh, off, ln, pcrc, hcrc)
        body += struct.pack("<I", 1) + struct.pack("<Q", N)  # dims
    with open(os.path.join(dirname, "index.cuszi"), "wb") as f:
        f.write(b"CUSZB1\x00\x00" + struct.pack("<I", 1) + section(body))


def main():
    codes = build_codes()
    outliers, verbatim = build_side_channels()
    expected = expected_field(codes, outliers, verbatim)

    os.makedirs(os.path.join(HERE, "expected"), exist_ok=True)
    with open(os.path.join(HERE, "expected", "fixture_field.f32"), "wb") as f:
        f.write(expected)

    huff_aux, huff = huffman_chunks(codes)
    fle_aux, fle = fle_chunks(codes)
    body_huff = body_bytes(huff_aux, huff, outliers, verbatim)
    body_fle = body_bytes(fle_aux, fle, outliers, verbatim)

    # CUSZA1: pre-codec layout, implicit huffman, abs eb, no lossless
    v0 = archive_bytes(
        b"CUSZA1\x00\x00",
        header_bytes(0, 0, "fixture/v0-huffman", 0, ABS_EB, 32, 0),
        body_huff,
    )
    # CUSZA2: version-1 header, huffman tag, valrel eb mode, gzip body
    v1_gz = archive_bytes(
        b"CUSZA2\x00\x00",
        header_bytes(1, 0, "fixture/v1-huffman-gzip", 1, 1e-3, 32, 1),
        body_huff,
        gzip_body=True,
    )
    # CUSZA2: version-1 header, FLE tag, abs eb, no lossless
    v1_fle = archive_bytes(
        b"CUSZA2\x00\x00",
        header_bytes(1, 1, "fixture/v1-fle", 0, ABS_EB, max(fle_aux), 0),
        body_fle,
    )

    # CUSZA3 / format version 3: granularity byte in the header, tag-table
    # section in the body (empty at field granularity), segmented gzip
    # tail. Small 16 KiB segments force a real multi-segment table on the
    # ~84 KB body (the Rust writer's floor is larger; readers accept any).
    body_fle_v3 = body_bytes(fle_aux, fle, outliers, verbatim, version=3)
    v3_fle = archive_bytes(
        b"CUSZA3\x00\x00",
        header_bytes(3, 1, "fixture/v3-fle", 0, ABS_EB, max(fle_aux), 0),
        body_fle_v3,
    )
    body_huff_v3 = body_bytes(huff_aux, huff, outliers, verbatim, version=3)
    v3_gzseg = archive_bytes(
        b"CUSZA3\x00\x00",
        header_bytes(3, 0, "fixture/v3-huffman-gzipseg", 1, 1e-3, 32, 1),
        body_huff_v3,
        gzip_seg_bytes=16 * 1024,
    )
    # chunk granularity: even chunks huffman (sharing the all-10 codebook
    # in the field aux), odd chunks FLE (1-byte width sidecar records)
    mixed_chunks, mixed_tags, mixed_aux = [], [], []
    for ci in range(len(huff)):
        if ci % 2 == 0:
            mixed_chunks.append(huff[ci])
            mixed_tags.append(0)
            mixed_aux.append(b"")
        else:
            mixed_chunks.append(fle[ci])
            mixed_tags.append(1)
            mixed_aux.append(bytes([fle_aux[ci]]))
    body_mixed_v3 = body_bytes(huff_aux, mixed_chunks, outliers, verbatim,
                               version=3, chunk_tags=mixed_tags, chunk_aux=mixed_aux)
    v3_mixed = archive_bytes(
        b"CUSZA3\x00\x00",
        header_bytes(3, 0, "fixture/v3-mixed-gzipseg", 0, ABS_EB, 32, 1, granularity=1),
        body_mixed_v3,
        gzip_seg_bytes=16 * 1024,
    )

    # CUSZA4 / format version 4: per-chunk Huffman gap tables. Larger
    # 16384-symbol chunks so each chunk carries a real 4-entry table
    # (4096-symbol chunks would record none); no lossless tail, so the
    # Rust writer's gap-section framing is locked byte-for-byte against
    # this independent mirror.
    CHUNK_V4 = 16384
    _, huff_v4 = huffman_chunks(codes, chunk=CHUNK_V4)
    gaps_v4 = gap_tables_for(huff_v4)
    assert all(len(t) == CHUNK_V4 // GAP_SUBCHUNK for t in gaps_v4)
    body_huff_v4 = body_bytes(huff_aux, huff_v4, outliers, verbatim, version=4,
                              chunk_symbols=CHUNK_V4, gap_tables=gaps_v4)
    v4_gap = archive_bytes(
        b"CUSZA4\x00\x00",
        header_bytes(4, 0, "fixture/v4-huffman-gap", 0, ABS_EB, 32, 0,
                     chunk_symbols=CHUNK_V4),
        body_huff_v4,
    )

    for name, data in [
        ("v0_huffman_none.cusza", v0),
        ("v1_huffman_gzip.cusza", v1_gz),
        ("v1_fle_none.cusza", v1_fle),
        ("v3_fle_none.cusza", v3_fle),
        ("v3_huffman_gzipseg.cusza", v3_gzseg),
        ("v3_mixed_gzipseg.cusza", v3_mixed),
        ("v4_huffman_gap.cusza", v4_gap),
    ]:
        with open(os.path.join(HERE, name), "wb") as f:
            f.write(data)
        print(f"{name}: {len(data)} bytes")

    bundle(
        os.path.join(HERE, "bundle_v1.cuszb"),
        [
            ("fixture/v0-huffman", v0,
             header_bytes(0, 0, "fixture/v0-huffman", 0, ABS_EB, 32, 0)),
            ("fixture/v1-fle", v1_fle,
             header_bytes(1, 1, "fixture/v1-fle", 0, ABS_EB, max(fle_aux), 0)),
        ],
    )
    print("bundle_v1.cuszb written")
    print(f"expected field: {len(expected)} bytes, eb {ABS_EB}")


if __name__ == "__main__":
    main()
