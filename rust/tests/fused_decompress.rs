//! Fused decompress path acceptance tests: thread-count invariance
//! (threads 1 vs 8 produce byte-identical fields across the codec ×
//! granularity matrix), bit-equivalence with the pre-fusion
//! materializing baseline, the no-whole-field-symbol-buffer probe
//! (`zero_copy.rs`-style regression lock), and hostile outlier/verbatim
//! side channels failing cleanly under the per-slab `partition_point`
//! split.

use cusz::codec::{self, CodecGranularity, CodecSpec, EncoderChoice};
use cusz::config::{BackendKind, CuszConfig, ErrorBound, LosslessStage};
use cusz::container::Archive;
use cusz::coordinator::Coordinator;
use cusz::field::Field;
use cusz::metrics;
use cusz::testkit::fields::{make, Regime};

const EB: f32 = 1e-3;

fn coordinator(codec: CodecSpec, threads: usize) -> Coordinator {
    Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(EB as f64),
        codec,
        threads,
        ..Default::default()
    })
    .unwrap()
}

/// A multi-slab field that exercises every side channel: rough data for
/// prediction outliers, plus non-finite and huge values for verbatim.
fn spiky_field(n: usize, seed: u64) -> Field {
    let mut data = make(Regime::Noisy, n, seed);
    data[7] = f32::NAN;
    data[n / 2] = f32::INFINITY;
    data[n - 3] = 3.4e38;
    Field::new(format!("fused-{seed}"), vec![n], data).unwrap()
}

#[test]
fn thread_count_invariance_across_the_codec_matrix() {
    let n = 1 << 17; // two 1d_64k slabs
    for encoder in [
        EncoderChoice::Huffman,
        EncoderChoice::Fle,
        EncoderChoice::Rle,
        EncoderChoice::Auto,
    ] {
        for granularity in [CodecGranularity::Field, CodecGranularity::Chunk] {
            let codec = CodecSpec { encoder, lossless: LosslessStage::Zstd, granularity };
            let field = spiky_field(n, 11);
            let c1 = coordinator(codec, 1);
            let c8 = coordinator(codec, 8);
            let bytes = c1.compress_encoded(&field).unwrap().bytes;
            let a1 = Archive::from_bytes_with_threads(&bytes, 1).unwrap();
            let a8 = Archive::from_bytes_with_threads(&bytes, 8).unwrap();
            let (f1, s1) = c1.decompress_with_stats(&a1).unwrap();
            let (f8, s8) = c8.decompress_with_stats(&a8).unwrap();
            assert_eq!(s1.threads, 1, "{encoder:?}/{granularity:?}");
            assert_eq!(s8.threads, 8, "{encoder:?}/{granularity:?}");
            let bits = |f: &Field| f.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&f1), bits(&f8), "{encoder:?}/{granularity:?}: threads 1 vs 8");
            // and the fused path is bit-identical to the materializing
            // baseline it replaced
            let (fb, _) = c1.decompress_materializing(&a1).unwrap();
            assert_eq!(bits(&f1), bits(&fb), "{encoder:?}/{granularity:?}: fused vs baseline");
            // NaN compares unequal, so check the bound on the finite side
            // and the specials explicitly
            assert!(f1.data[7].is_nan());
            assert_eq!(f1.data[n / 2], f32::INFINITY);
            assert_eq!(f1.data[n - 3], 3.4e38);
            let finite: Vec<f32> = field
                .data
                .iter()
                .map(|&v| if v.is_finite() { v } else { 0.0 })
                .collect();
            let out_finite: Vec<f32> = f1
                .data
                .iter()
                .map(|&v| if v.is_finite() { v } else { 0.0 })
                .collect();
            assert_eq!(
                metrics::verify_error_bound(&finite, &out_finite, EB),
                None,
                "{encoder:?}/{granularity:?}"
            );
        }
    }
}

/// THE regression lock for the fused path: decompressing a field must
/// materialize no whole-field symbol buffer. The probe is a thread-local
/// counter bumped by the materializing decode adapters — the fused
/// `decode_into` sink path never touches it, and the kept baseline
/// demonstrates the probe actually fires.
#[test]
fn fused_path_materializes_no_whole_field_symbol_buffer() {
    for granularity in [CodecGranularity::Field, CodecGranularity::Chunk] {
        let codec = CodecSpec {
            encoder: EncoderChoice::Auto,
            lossless: LosslessStage::Zstd,
            granularity,
        };
        let coord = coordinator(codec, 4);
        let field = spiky_field(1 << 17, 3);
        let bytes = coord.compress_encoded(&field).unwrap().bytes;
        let archive = Archive::from_bytes(&bytes).unwrap();

        let before = codec::symbol_buffer_materializations();
        let _ = coord.decompress(&archive).unwrap();
        assert_eq!(
            codec::symbol_buffer_materializations() - before,
            0,
            "{granularity:?}: the fused path must not build a whole-field symbol buffer"
        );
        // sanity: the baseline does exactly one materialization, so the
        // probe is live and counting on this thread
        let _ = coord.decompress_materializing(&archive).unwrap();
        assert_eq!(
            codec::symbol_buffer_materializations() - before,
            1,
            "{granularity:?}: the materializing baseline must bump the probe once"
        );
    }
}

/// Hostile side channels must fail cleanly under the per-slab
/// `partition_point` split: out-of-range, unsorted, and duplicate
/// positions all error (no panic, no wrong output), exactly as the old
/// whole-channel validation scan did.
#[test]
fn hostile_outlier_and_verbatim_channels_fail_cleanly() {
    let coord = coordinator(CodecSpec::default(), 4);
    let field = spiky_field(100_000, 9); // two slabs, padding in the last
    let archive = coord.compress(&field).unwrap();
    // sanity: the untouched archive decodes
    coord.decompress(&archive).unwrap();
    let slab_len: u64 = 1 << 16;

    // outlier past the end of the slab stream
    let mut a = archive.clone();
    a.outliers.push((2 * slab_len, 1));
    assert!(coord.decompress(&a).is_err(), "out-of-range outlier");

    // unsorted outliers within one slab
    let mut a = archive.clone();
    a.outliers = vec![(10, 1), (5, 2)];
    assert!(coord.decompress(&a).is_err(), "unsorted outliers");

    // duplicate outlier positions
    let mut a = archive.clone();
    a.outliers = vec![(7, 1), (7, 2)];
    assert!(coord.decompress(&a).is_err(), "duplicate outliers");

    // unsorted across slabs: a slab-1 position before a slab-0 position
    let mut a = archive.clone();
    a.outliers = vec![(slab_len + 5, 1), (5, 2)];
    assert!(coord.decompress(&a).is_err(), "cross-slab unsorted outliers");

    // verbatim past the end of the slab stream
    let mut a = archive.clone();
    a.verbatim.push((u64::MAX, 1.0));
    assert!(coord.decompress(&a).is_err(), "out-of-range verbatim");

    // verbatim unsorted across slabs (within-slab order is free — the
    // owning worker applies its range in list order)
    let mut a = archive.clone();
    a.verbatim = vec![(slab_len + 5, 1.0), (5, 2.0)];
    assert!(coord.decompress(&a).is_err(), "cross-slab unsorted verbatim");
}

/// The gap-array acceptance shape: ONE deflate chunk covering the whole
/// field, so chunk-level parallelism is zero and only the gap-table
/// subchunk fan-out can use the thread budget. The decode must stay
/// bit-identical to the serial path at every budget.
#[test]
fn single_chunk_gap_decode_is_thread_invariant() {
    let n = 1 << 16; // one 1d_64k slab = one 64k-symbol deflate chunk
    let field = spiky_field(n, 5);
    let mk = |threads: usize| {
        Coordinator::new(CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::Abs(EB as f64),
            chunk_symbols: n,
            threads,
            ..Default::default()
        })
        .unwrap()
    };
    let c1 = mk(1);
    let bytes = c1.compress_encoded(&field).unwrap().bytes;
    let archive = Archive::from_bytes(&bytes).unwrap();
    assert_eq!(archive.stream.chunks.len(), 1, "field must be one deflate chunk");
    assert_eq!(archive.gap_tables.len(), 1, "the chunk must carry a gap table");
    assert_eq!(archive.gap_tables[0].len(), n / cusz::huffman::GAP_SUBCHUNK);
    let bits = |f: &Field| f.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    let (f1, s1) = c1.decompress_with_stats(&archive).unwrap();
    assert_eq!(s1.threads, 1);
    for threads in [2usize, 8] {
        let (ft, st) = mk(threads).decompress_with_stats(&archive).unwrap();
        assert_eq!(st.threads, threads);
        assert_eq!(bits(&f1), bits(&ft), "threads 1 vs {threads}");
    }
    // a gap-stripped copy (the pure serial path) agrees bit for bit
    let mut serial = archive.clone();
    serial.gap_tables = Vec::new();
    let (fs, _) = mk(8).decompress_with_stats(&serial).unwrap();
    assert_eq!(bits(&f1), bits(&fs), "gap vs serial decode");
}

/// The serve-side drain hands its per-job thread budget to the fused
/// pass; a budget of 1 must behave exactly like any other (already
/// covered above) and the stats must report what actually ran.
#[test]
fn explicit_thread_budget_is_reported_in_stats() {
    let coord = coordinator(CodecSpec::default(), 0);
    let field = spiky_field(1 << 16, 21);
    let archive = coord.compress(&field).unwrap();
    for budget in [1usize, 3] {
        let (out, stats) = coord.decompress_with_threads(&archive, budget).unwrap();
        assert_eq!(stats.threads, budget);
        assert_eq!(out.dims, field.dims);
    }
    // the default entry point resolves the config budget (0 = all cores)
    let (_, stats) = coord.decompress_with_stats(&archive).unwrap();
    assert!(stats.threads >= 1);
}
