//! Property-based integration tests (testkit::prop): coordinator
//! invariants over randomized shapes, error bounds, and data regimes —
//! the L3 analogue of the python hypothesis suite.

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::field::Field;
use cusz::huffman::{self, CanonicalCodebook, ReverseCodebook};
use cusz::metrics;
use cusz::testkit::prop::{check, gen};
use cusz::util::prng::Rng;

fn coordinator(eb: f64) -> Coordinator {
    Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(eb),
        ..Default::default()
    })
    .unwrap()
}

fn random_field(rng: &mut Rng) -> (Field, f64) {
    let ndim = gen::usize_in(rng, 1, 3);
    let dims: Vec<usize> = (0..ndim).map(|_| gen::usize_in(rng, 5, 90)).collect();
    let n: usize = dims.iter().product();
    let scale = *gen::pick(rng, &[1e-3f32, 1.0, 100.0]);
    let mut data = gen::f32_vec(rng, n, scale);
    // random smoothing pass to vary predictability
    if rng.f32() < 0.5 {
        for i in 1..data.len() {
            data[i] = data[i - 1] + data[i] * 0.1;
        }
    }
    let eb = *gen::pick(rng, &[1e-1f64, 1e-2, 1e-3]) * scale as f64;
    (Field::new("prop", dims, data).unwrap(), eb)
}

#[test]
fn prop_roundtrip_error_bound() {
    check("coordinator roundtrip obeys eb", |rng| {
        let (field, eb) = random_field(rng);
        let coord = coordinator(eb);
        let archive = coord.compress(&field).map_err(|e| e.to_string())?;
        let out = coord.decompress(&archive).map_err(|e| e.to_string())?;
        if out.dims != field.dims {
            return Err("dims mismatch".into());
        }
        match metrics::verify_error_bound(&field.data, &out.data, eb as f32) {
            None => Ok(()),
            Some(i) => Err(format!(
                "bound violated at {i}: {} vs {} (eb {eb})",
                field.data[i], out.data[i]
            )),
        }
    });
}

#[test]
fn prop_archive_bytes_roundtrip() {
    check("archive serialization is lossless", |rng| {
        let (field, eb) = random_field(rng);
        let coord = coordinator(eb);
        let a = coord.compress(&field).map_err(|e| e.to_string())?;
        let b = cusz::container::Archive::from_bytes(&a.to_bytes()).map_err(|e| e.to_string())?;
        if a != b {
            return Err("archive != from_bytes(to_bytes(archive))".into());
        }
        Ok(())
    });
}

#[test]
fn prop_huffman_roundtrip_random_distributions() {
    check("huffman deflate/inflate identity", |rng| {
        let dict = *gen::pick(rng, &[16usize, 256, 1024]);
        let n = gen::usize_in(rng, 1, 30_000);
        // mixture: sometimes uniform, sometimes highly skewed
        let skew = rng.f32() < 0.5;
        let syms: Vec<u16> = (0..n)
            .map(|_| {
                if skew {
                    let z = (rng.normal().abs() * (dict as f32) / 20.0) as usize;
                    z.min(dict - 1) as u16
                } else {
                    rng.below(dict as u64) as u16
                }
            })
            .collect();
        let hist = huffman::histogram(&syms, dict);
        let freq: Vec<u64> = hist.iter().map(|&c| c as u64).collect();
        let lengths = huffman::build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).map_err(|e| e.to_string())?;
        let rev = ReverseCodebook::from_lengths(&lengths).map_err(|e| e.to_string())?;
        let chunk = *gen::pick(rng, &[64usize, 1000, 4096]);
        let stream = huffman::deflate_chunks(&syms, &book, chunk, 4);
        let out =
            huffman::inflate::inflate_chunks_strict(&stream, &rev, 4).map_err(|e| e.to_string())?;
        if out != syms {
            return Err("symbol stream mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_versioned_header_roundtrip_and_tag_rejection() {
    use cusz::codec::{CodecGranularity, EncoderKind};
    use cusz::container::{Header, LosslessTag, FORMAT_VERSION};

    check("versioned header roundtrips; unknown tags/versions rejected", |rng| {
        let nd = gen::usize_in(rng, 1, 4);
        let dims: Vec<usize> = (0..nd).map(|_| gen::usize_in(rng, 1, 4096)).collect();
        let h = Header {
            version: FORMAT_VERSION,
            encoder: *gen::pick(rng, &EncoderKind::ALL),
            granularity: *gen::pick(rng, &[CodecGranularity::Field, CodecGranularity::Chunk]),
            field_name: format!("f{}", gen::usize_in(rng, 0, 9999)),
            dims,
            variant: "2d_256".into(),
            eb: if rng.f32() < 0.5 {
                cusz::config::ErrorBound::Abs(0.5)
            } else {
                cusz::config::ErrorBound::ValRel(1e-4)
            },
            abs_eb: 0.5,
            dict_size: *gen::pick(rng, &[128usize, 1024, 65536]),
            chunk_symbols: *gen::pick(rng, &[64usize, 4096, 65536]),
            repr_bits: *gen::pick(rng, &[17u32, 32, 64]),
            lossless: *gen::pick(rng, &[LosslessTag::None, LosslessTag::Gzip, LosslessTag::Zstd]),
            n_slabs: gen::usize_in(rng, 1, 1000),
        };
        let bytes = h.to_bytes();
        let back = Header::from_bytes(&bytes).map_err(|e| e.to_string())?;
        if back != h {
            return Err("versioned roundtrip mismatch".into());
        }

        // the old (version-0) layout still parses via the legacy path
        let mut h0 = h.clone();
        h0.version = 0;
        h0.encoder = EncoderKind::Huffman;
        h0.granularity = CodecGranularity::Field;
        let back0 = Header::from_bytes_v0(&h0.to_bytes()).map_err(|e| e.to_string())?;
        if back0 != h0 {
            return Err("v0 roundtrip mismatch".into());
        }

        // unknown encoder tag: rejected without panic
        let mut bad = bytes.clone();
        bad[1] = 3 + rng.below(253) as u8;
        if Header::from_bytes(&bad).is_ok() {
            return Err(format!("unknown encoder tag {} accepted", bad[1]));
        }

        // unknown granularity tag: rejected without panic
        let mut bad = bytes.clone();
        bad[2] = 2 + rng.below(254) as u8;
        if Header::from_bytes(&bad).is_ok() {
            return Err(format!("unknown granularity tag {} accepted", bad[2]));
        }

        // future format version: rejected without panic
        let mut fut = bytes.clone();
        fut[0] = FORMAT_VERSION + 1 + rng.below(200) as u8;
        if Header::from_bytes(&fut).is_ok() {
            return Err(format!("future version {} accepted", fut[0]));
        }

        // any proper prefix errors, never panics
        let cut = gen::usize_in(rng, 0, bytes.len() - 1);
        if Header::from_bytes(&bytes[..cut]).is_ok() {
            return Err(format!("truncated header ({cut}/{} bytes) parsed", bytes.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_codec_matrix_roundtrip_error_bound() {
    use cusz::codec::{CodecGranularity, CodecSpec, EncoderChoice};
    use cusz::config::LosslessStage;

    check("every codec combination obeys eb through archive bytes", |rng| {
        let (field, eb) = random_field(rng);
        let codec = CodecSpec {
            encoder: *gen::pick(
                rng,
                &[EncoderChoice::Huffman, EncoderChoice::Fle, EncoderChoice::Auto],
            ),
            lossless: *gen::pick(rng, &[LosslessStage::None, LosslessStage::Zstd]),
            granularity: *gen::pick(rng, &[CodecGranularity::Field, CodecGranularity::Chunk]),
        };
        let coord = Coordinator::new(CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::Abs(eb),
            codec,
            ..Default::default()
        })
        .unwrap();
        let archive = coord.compress(&field).map_err(|e| e.to_string())?;
        let restored = cusz::container::Archive::from_bytes(&archive.to_bytes())
            .map_err(|e| e.to_string())?;
        let out = coord.decompress(&restored).map_err(|e| e.to_string())?;
        match metrics::verify_error_bound(&field.data, &out.data, eb as f32) {
            None => Ok(()),
            Some(i) => Err(format!(
                "{codec:?}: bound violated at {i}: {} vs {}",
                field.data[i], out.data[i]
            )),
        }
    });
}

#[test]
fn prop_streaming_writer_matches_to_bytes_and_len() {
    use cusz::codec::{CodecSpec, EncoderChoice};
    use cusz::config::LosslessStage;
    use cusz::container::Archive;

    check("write_into == to_bytes; serialized_len == len; roundtrip", |rng| {
        let (field, eb) = random_field(rng);
        let codec = CodecSpec {
            encoder: *gen::pick(
                rng,
                &[EncoderChoice::Huffman, EncoderChoice::Fle, EncoderChoice::Rle],
            ),
            lossless: *gen::pick(
                rng,
                &[LosslessStage::None, LosslessStage::Gzip, LosslessStage::Zstd],
            ),
            ..Default::default()
        };
        let coord = Coordinator::new(CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::Abs(eb),
            codec,
            ..Default::default()
        })
        .unwrap();
        let archive = coord.compress(&field).map_err(|e| e.to_string())?;
        let bytes = archive.to_bytes();
        let mut streamed = Vec::new();
        let n = archive.write_into(&mut streamed).map_err(|e| e.to_string())?;
        if streamed != bytes {
            return Err(format!("{codec:?}: write_into differs from to_bytes"));
        }
        if n as usize != bytes.len() || archive.serialized_len() != bytes.len() {
            return Err(format!(
                "{codec:?}: serialized_len {} / written {n} != {}",
                archive.serialized_len(),
                bytes.len()
            ));
        }
        let back = Archive::from_bytes(&bytes).map_err(|e| e.to_string())?;
        if back != archive {
            return Err(format!("{codec:?}: archive != from_bytes(write_into(archive))"));
        }
        Ok(())
    });
}

#[test]
fn prop_archive_rejects_truncation_and_bitflips() {
    check("archive parser errors (never panics) on corrupt bytes", |rng| {
        // small field keeps each case cheap; regimes vary via smoothing
        let ndim = gen::usize_in(rng, 1, 2);
        let dims: Vec<usize> = (0..ndim).map(|_| gen::usize_in(rng, 5, 50)).collect();
        let n: usize = dims.iter().product();
        let data = gen::f32_vec(rng, n, 1.0);
        let field = Field::new("corrupt", dims, data).unwrap();
        let coord = coordinator(1e-2);
        let bytes = coord
            .compress(&field)
            .map_err(|e| e.to_string())?
            .to_bytes();

        // any proper prefix must be rejected
        let cut = gen::usize_in(rng, 0, bytes.len() - 1);
        if cusz::container::Archive::from_bytes(&bytes[..cut]).is_ok() {
            return Err(format!("truncated archive ({cut}/{} bytes) parsed", bytes.len()));
        }

        // any single bit flip lands in the magic, a section frame, or
        // CRC-covered payload — all must be rejected
        let pos = gen::usize_in(rng, 0, bytes.len() - 1);
        let bit = gen::usize_in(rng, 0, 7);
        let mut flipped = bytes.clone();
        flipped[pos] ^= 1 << bit;
        if cusz::container::Archive::from_bytes(&flipped).is_ok() {
            return Err(format!("bit flip at {pos}:{bit} parsed"));
        }
        Ok(())
    });
}

#[test]
fn prop_chunk_tag_and_sidecar_corruption_fails_cleanly() {
    use cusz::codec::{CodecGranularity, CodecSpec, EncoderChoice};
    use cusz::container::Archive;

    // one coordinator for every case: per-chunk auto over a field that
    // stitches constant, smooth, and noisy segments, so archives carry a
    // real mixed tag table (rle + huffman/fle chunks)
    let coord = Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(1e-2),
        codec: CodecSpec {
            encoder: EncoderChoice::Auto,
            granularity: CodecGranularity::Chunk,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();

    check("per-chunk tag/sidecar corruption errors, never panics", |rng| {
        let n = 1 << 16; // one 1d_64k slab = 16 chunks
        let mut data = Vec::with_capacity(n);
        let mut acc = 0f32;
        let seg = gen::usize_in(rng, 3000, 9000);
        for i in 0..n {
            match (i / seg) % 3 {
                0 => data.push(0.0),
                1 => {
                    acc += rng.normal() * 0.01;
                    data.push(acc);
                }
                _ => data.push(rng.normal() * 3.0),
            }
        }
        let field = Field::new("prop-mixed", vec![n], data).unwrap();
        let archive = coord.compress(&field).map_err(|e| e.to_string())?;
        if archive.chunk_tags.is_empty() {
            return Err("per-chunk auto produced no tag table".into());
        }
        // sanity: the untouched archive decodes
        coord.decompress(&archive).map_err(|e| e.to_string())?;

        // structural mutations that bypass the CRCs (a hostile writer can
        // produce internally-consistent sections): decompress must error
        // without panicking and without allocating for inflated counts
        let mut a = archive.clone();
        let which = rng.below(6);
        let applied = match which {
            0 => {
                a.chunk_tags.pop();
                a.chunk_aux.pop();
                true
            }
            1 => {
                let i = gen::usize_in(rng, 0, a.chunk_tags.len() - 1);
                a.chunk_tags[i] = 3 + rng.below(253) as u8;
                true
            }
            2 => {
                // retag a chunk with a different (valid) backend: the
                // sidecar record length no longer matches
                let i = gen::usize_in(rng, 0, a.chunk_tags.len() - 1);
                match a.chunk_tags.iter().position(|&t| t != a.chunk_tags[i]) {
                    Some(j) => {
                        let t = a.chunk_tags[i];
                        a.chunk_tags[i] = a.chunk_tags[j];
                        a.chunk_tags[j] = t;
                        true
                    }
                    None => false,
                }
            }
            3 => {
                // blow past the RLE/FLE width ceilings
                match a.chunk_aux.iter().position(|r| !r.is_empty()) {
                    Some(i) => {
                        for b in a.chunk_aux[i].iter_mut() {
                            *b = 255;
                        }
                        true
                    }
                    None => false,
                }
            }
            4 => {
                // inflate a chunk's claimed symbol count: must be
                // rejected before any allocation matches it
                let i = gen::usize_in(rng, 0, a.stream.chunks.len() - 1);
                a.stream.chunks[i].symbols = u32::MAX;
                true
            }
            _ => {
                // truncate an RLE/FLE sidecar record
                match a.chunk_aux.iter().position(|r| !r.is_empty()) {
                    Some(i) => {
                        a.chunk_aux[i].pop();
                        true
                    }
                    None => false,
                }
            }
        };
        if applied && coord.decompress(&a).is_ok() {
            return Err(format!("mutation {which} decoded successfully"));
        }

        // and the byte path: a truncated or retagged table must not parse
        let mut b = archive.clone();
        b.chunk_tags.pop();
        b.chunk_aux.pop();
        if Archive::from_bytes(&b.to_bytes()).is_ok() {
            return Err("truncated tag table parsed from bytes".into());
        }
        Ok(())
    });
}

#[test]
fn prop_gap_decode_matches_serial_bit_for_bit() {
    use cusz::codec::{CodecGranularity, CodecSpec, EncoderChoice};
    use cusz::config::LosslessStage;

    check("gap-array parallel decode == serial decode", |rng| {
        // chunks well past GAP_SUBCHUNK so real gap tables are recorded
        let n = gen::usize_in(rng, 10_000, 90_000);
        let scale = *gen::pick(rng, &[1e-2f32, 1.0]);
        let data = gen::f32_vec(rng, n, scale);
        let field = Field::new("gap", vec![n], data).unwrap();
        let encoder = *gen::pick(rng, &[EncoderChoice::Huffman, EncoderChoice::Auto]);
        let granularity = *gen::pick(rng, &[CodecGranularity::Field, CodecGranularity::Chunk]);
        let chunk_symbols = *gen::pick(rng, &[8192usize, 16384, 65536]);
        let mk = |threads: usize| {
            Coordinator::new(CuszConfig {
                backend: BackendKind::Cpu,
                eb: ErrorBound::Abs(1e-2 * scale as f64),
                chunk_symbols,
                threads,
                codec: CodecSpec { encoder, granularity, lossless: LosslessStage::None },
                ..Default::default()
            })
            .unwrap()
        };
        let archive = mk(0).compress(&field).map_err(|e| e.to_string())?;
        if encoder == EncoderChoice::Huffman && archive.gap_tables.is_empty() {
            return Err("forced huffman with large chunks recorded no gap tables".into());
        }
        // the wire roundtrip preserves the gap sidecar exactly
        let restored = cusz::container::Archive::from_bytes(&archive.to_bytes())
            .map_err(|e| e.to_string())?;
        if restored.gap_tables != archive.gap_tables {
            return Err("gap tables changed across serialization".into());
        }
        let coord = mk(*gen::pick(rng, &[2usize, 4, 8]));
        let gap_out = coord.decompress(&restored).map_err(|e| e.to_string())?;
        // strip the sidecar: the serial path must produce the same bits
        let mut serial = restored;
        serial.gap_tables = Vec::new();
        let serial_out = coord.decompress(&serial).map_err(|e| e.to_string())?;
        let bits = |f: &Field| f.data.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        if bits(&gap_out) != bits(&serial_out) {
            return Err(format!("{encoder:?}/{granularity:?}: gap and serial decodes differ"));
        }
        Ok(())
    });
}

#[test]
fn prop_hostile_gap_tables_fail_cleanly() {
    // big chunks so every archive carries a real multi-entry gap table
    let coord = Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(1e-2),
        chunk_symbols: 16384,
        ..Default::default()
    })
    .unwrap();

    check("corrupt gap sidecars error, never panic", |rng| {
        let n = gen::usize_in(rng, 20_000, 70_000);
        let data = gen::f32_vec(rng, n, 1.0);
        let field = Field::new("hostile-gap", vec![n], data).unwrap();
        let archive = coord.compress(&field).map_err(|e| e.to_string())?;
        if archive.gap_tables.is_empty() || archive.gap_tables[0].len() < 2 {
            return Err("expected a multi-entry gap table".into());
        }
        // sanity: the untouched archive decodes
        coord.decompress(&archive).map_err(|e| e.to_string())?;

        // the offset table is untrusted input: every structural lie must
        // be rejected before any subchunk decodes — no panic, no output
        let mut a = archive.clone();
        let k = a.gap_tables[0].len();
        let which = rng.below(6);
        match which {
            0 => a.gap_tables[0][0].0 = 1,              // first offset not 0
            1 => a.gap_tables[0][k - 1].0 = u64::MAX,   // offset past the bitstream
            2 => a.gap_tables[0].swap(0, 1),            // offsets out of order
            3 => a.gap_tables[0][k - 1].1 = u32::MAX,   // inflated symbol count
            4 => a.gap_tables.push(Vec::new()),         // cardinality mismatch
            _ => a.gap_tables[0][k - 1].1 = 0,          // zero-symbol subchunk
        }
        if coord.decompress(&a).is_ok() {
            return Err(format!("gap mutation {which} decoded successfully"));
        }
        // and through the byte path: the parser either rejects the frame
        // outright or hands the decoder a table it then rejects
        match cusz::container::Archive::from_bytes(&a.to_bytes()) {
            Err(_) => {}
            Ok(r) => {
                if coord.decompress(&r).is_ok() {
                    return Err(format!("gap mutation {which} decoded from bytes"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_store_open_rejects_corrupt_index() {
    use cusz::store::Store;

    // one tiny bundle on disk; every case corrupts a copy of its index
    let dir = cusz::testkit::tmp_dir("prop-store");
    let coord = coordinator(1e-2);
    let mut store = Store::create(&dir, 2).unwrap();
    for i in 0..3u64 {
        let data: Vec<f32> = (0..2048).map(|k| ((k as f32) * 0.01).sin() + i as f32).collect();
        let field = Field::new(format!("f{i}"), vec![2048], data).unwrap();
        store.add(&coord.compress(&field).unwrap()).unwrap();
    }
    drop(store);
    let index_path = dir.join("index.cuszi");
    let good = std::fs::read(&index_path).unwrap();

    check("store open errors (never panics) on corrupt index", |rng| {
        let mut bad = good.clone();
        if rng.f32() < 0.5 {
            bad.truncate(gen::usize_in(rng, 0, bad.len() - 1));
        } else {
            let pos = gen::usize_in(rng, 0, bad.len() - 1);
            bad[pos] ^= 1 << gen::usize_in(rng, 0, 7);
        }
        std::fs::write(&index_path, &bad).map_err(|e| e.to_string())?;
        if Store::open(&dir).is_ok() {
            return Err("corrupt index opened".into());
        }
        Ok(())
    });

    // restore and confirm the bundle is intact again
    std::fs::write(&index_path, &good).unwrap();
    Store::open(&dir).unwrap().verify().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn prop_zfp_rate_size_and_monotonicity() {
    check("zfp fixed rate gives fixed size", |rng| {
        let ndim = gen::usize_in(rng, 1, 3);
        let dims: Vec<usize> = (0..ndim).map(|_| gen::usize_in(rng, 4, 40)).collect();
        let n: usize = dims.iter().product();
        let data = gen::f32_vec(rng, n, 10.0);
        let rate = *gen::pick(rng, &[4.0f64, 8.0, 16.0]);
        let z = cusz::zfp::Zfp::new(rate);
        let s = z.compress(&data, &dims).map_err(|e| e.to_string())?;
        let blocks: usize = dims.iter().map(|d| d.div_ceil(4)).product();
        let per_block = s.bits as usize / blocks;
        // fixed rate: every block gets the same bit budget
        if s.bits as usize % blocks != 0 {
            return Err(format!("bits {} not divisible by {blocks} blocks", s.bits));
        }
        let _ = per_block;
        let out = z.decompress(&s).map_err(|e| e.to_string())?;
        if out.len() != n {
            return Err("length mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_classic_sz_bound() {
    check("classic SZ honors eb", |rng| {
        let ndim = gen::usize_in(rng, 1, 3);
        let dims: Vec<usize> = (0..ndim).map(|_| gen::usize_in(rng, 4, 30)).collect();
        let n: usize = dims.iter().product();
        let data = gen::f32_vec(rng, n, 5.0);
        let eb = 1e-2f32;
        let c = cusz::sz::classic::compress(&data, &dims, eb, 1024);
        let out = cusz::sz::classic::decompress(&c, eb, 1024);
        for (i, (a, b)) in data.iter().zip(&out).enumerate() {
            if (a - b).abs() > eb * 1.0001 + 4.0 * f32::EPSILON * a.abs() {
                return Err(format!("violation at {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

// ---- wire protocol parser (serve daemon front end) ----
//
// Hostile-input battery in the same style as the sidecar/tag corruption
// props above: truncated frames (the reader-side shape of slow-loris
// partial writes), oversized declared lengths, and garbage bytes must
// all produce clean errors under tight allocation limits — no panics,
// no unbounded buffers.

#[test]
fn prop_wire_request_roundtrip() {
    use cusz::serve::wire::{self, Limits, Request};
    use std::io::Cursor;
    check("wire request roundtrips through the parser", |rng| {
        let req = match rng.below(4) {
            0 => {
                let ndim = gen::usize_in(rng, 1, 4);
                let dims: Vec<usize> = (0..ndim).map(|_| gen::usize_in(rng, 1, 10)).collect();
                let n: usize = dims.iter().product();
                let data = gen::f32_vec(rng, n, 10.0);
                let name = format!("f-{}", rng.below(1000));
                Request::Put { field: Field::new(name, dims, data).unwrap() }
            }
            1 => Request::Get { name: format!("g-{}", rng.below(1000)) },
            2 => Request::Stats,
            _ => Request::Ping,
        };
        let bytes = wire::encode_request(&req).map_err(|e| e.to_string())?;
        let mut cursor = Cursor::new(bytes);
        let parsed = wire::read_request(&mut cursor, &Limits::default())
            .map_err(|e| e.to_string())?
            .ok_or("unexpected clean EOF")?;
        if parsed != req {
            return Err("roundtrip mismatch".into());
        }
        // a second read at the frame boundary is a clean EOF, not an error
        match wire::read_request(&mut cursor, &Limits::default()) {
            Ok(None) => Ok(()),
            other => Err(format!("expected clean EOF after the frame, got {other:?}")),
        }
    });
}

#[test]
fn prop_wire_truncation_fails_cleanly() {
    use cusz::serve::wire::{self, Limits, Request};
    use std::io::Cursor;
    check("truncated frames error, never panic or parse", |rng| {
        let req = if rng.below(2) == 0 {
            let n = gen::usize_in(rng, 1, 64);
            let data = gen::f32_vec(rng, n, 1.0);
            Request::Put { field: Field::new("t", vec![n], data).unwrap() }
        } else {
            Request::Get { name: "a-name-long-enough-to-cut".into() }
        };
        let bytes = wire::encode_request(&req).map_err(|e| e.to_string())?;
        let cut = gen::usize_in(rng, 0, bytes.len() - 1);
        let mut cursor = Cursor::new(bytes[..cut].to_vec());
        match wire::read_request(&mut cursor, &Limits::default()) {
            // nothing sent at all: a clean close, not an error
            Ok(None) if cut == 0 => Ok(()),
            Ok(None) => Err(format!("mid-frame EOF at {cut} reported as clean close")),
            Ok(Some(_)) => Err(format!("parsed a request from {cut} truncated bytes")),
            Err(_) => Ok(()), // Malformed or Io — both clean outcomes
        }
    });
}

#[test]
fn prop_wire_garbage_fails_cleanly() {
    use cusz::serve::wire::{self, Limits};
    use std::io::Cursor;
    check("garbage bytes error under tight limits", |rng| {
        let n = gen::usize_in(rng, 1, 96);
        let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let limits = Limits { max_name_bytes: 64, max_body_bytes: 4096 };
        match wire::read_request(&mut Cursor::new(bytes), &limits) {
            Ok(Some(_)) => Err("parsed a request out of random garbage".into()),
            Ok(None) => Err("garbage reported as clean close".into()),
            Err(_) => Ok(()),
        }
    });
}

#[test]
fn prop_wire_oversized_declared_lengths_rejected() {
    use cusz::serve::wire::{self, Limits, WireError};
    use std::io::Cursor;
    check("oversized declared lengths rejected before allocation", |rng| {
        // hand-craft a header whose declared name/body lengths blow past
        // the limits; the parser must reject on the declaration alone
        let oversize_name = rng.below(2) == 0;
        let name_len: u16 =
            if oversize_name { gen::usize_in(rng, 65, u16::MAX as usize) as u16 } else { 4 };
        let body_len: u32 = if oversize_name {
            gen::usize_in(rng, 0, 4096) as u32
        } else {
            gen::usize_in(rng, 4097, u32::MAX as usize) as u32
        };
        let mut frame = Vec::new();
        frame.extend_from_slice(b"cZ");
        frame.push(1); // version
        frame.push(1); // opcode PUT
        frame.extend_from_slice(&name_len.to_le_bytes());
        frame.extend_from_slice(&[0, 0]); // reserved
        frame.extend_from_slice(&body_len.to_le_bytes());
        // far less trailing data than declared: allocation of the declared
        // size would be the bug this prop locks out
        frame.extend_from_slice(&vec![0xAB; gen::usize_in(rng, 0, 32)]);
        let limits = Limits { max_name_bytes: 64, max_body_bytes: 4096 };
        match wire::read_request(&mut Cursor::new(frame), &limits) {
            Err(WireError::Malformed(msg)) if !msg.is_empty() => Ok(()),
            other => Err(format!("expected Malformed with a message, got {other:?}")),
        }
    });
}

#[test]
fn prop_fsck_classifies_mutilations_and_repair_converges() {
    use cusz::store::fsck::{fsck, scan};
    use cusz::store::{FsckOptions, Store, StoreIndex};

    // one pristine two-shard bundle, snapshotted in memory; every case
    // restores the snapshot and then mutilates a fresh copy
    let dir = cusz::testkit::tmp_dir("prop-fsck");
    let coord = coordinator(1e-2);
    let mut store = Store::create(&dir, 2).unwrap();
    for i in 0..4u64 {
        let data: Vec<f32> =
            (0..1500).map(|k| ((k as f32) * 0.02).sin() * (i + 1) as f32).collect();
        let field = Field::new(format!("f{i}"), vec![1500], data).unwrap();
        store.add(&coord.compress(&field).unwrap()).unwrap();
    }
    drop(store);
    let pristine: Vec<(String, Vec<u8>)> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| {
            let e = e.unwrap();
            (e.file_name().to_string_lossy().into_owned(), std::fs::read(e.path()).unwrap())
        })
        .collect();
    let restore = |dir: &std::path::Path| {
        for entry in std::fs::read_dir(dir).unwrap() {
            let p = entry.unwrap().path();
            if p.is_dir() {
                std::fs::remove_dir_all(&p).unwrap();
            } else {
                std::fs::remove_file(&p).unwrap();
            }
        }
        for (name, bytes) in &pristine {
            std::fs::write(dir.join(name), bytes).unwrap();
        }
    };
    let shard_path =
        |dir: &std::path::Path, i: u64| dir.join(format!("shard-{i:04}.cuszs"));

    check("fsck classifies random mutilations; repair converges", |rng| {
        restore(&dir);
        for _ in 0..gen::usize_in(rng, 1, 3) {
            match rng.below(8) {
                0 => {
                    // payload / framing bit flip inside a shard (skipped
                    // if an earlier mutilation already deleted it)
                    let p = shard_path(&dir, rng.below(2));
                    let Ok(mut b) = std::fs::read(&p) else { continue };
                    if !b.is_empty() {
                        let pos = gen::usize_in(rng, 0, b.len() - 1);
                        b[pos] ^= 1 << gen::usize_in(rng, 0, 7);
                        std::fs::write(&p, &b).map_err(|e| e.to_string())?;
                    }
                }
                1 => {
                    // torn write: truncate a shard anywhere, even mid-magic
                    let p = shard_path(&dir, rng.below(2));
                    let Ok(meta) = std::fs::metadata(&p) else { continue };
                    let keep = rng.below(meta.len() + 1);
                    std::fs::OpenOptions::new()
                        .write(true)
                        .open(&p)
                        .and_then(|f| f.set_len(keep))
                        .map_err(|e| e.to_string())?;
                }
                2 => {
                    // torn append: unindexed garbage at a shard tail
                    let p = shard_path(&dir, rng.below(2));
                    let n = gen::usize_in(rng, 1, 2048);
                    let junk: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                    let Ok(mut b) = std::fs::read(&p) else { continue };
                    b.extend_from_slice(&junk);
                    std::fs::write(&p, &b).map_err(|e| e.to_string())?;
                }
                3 => {
                    let _ = std::fs::remove_file(shard_path(&dir, rng.below(2)));
                }
                4 => {
                    // index tampering at the byte level (usually fatal:
                    // the framing CRC catches it)
                    let p = dir.join("index.cuszi");
                    let mut b = std::fs::read(&p).map_err(|e| e.to_string())?;
                    if rng.below(4) == 0 {
                        b.truncate(gen::usize_in(rng, 0, b.len().saturating_sub(1)));
                    } else if !b.is_empty() {
                        let pos = gen::usize_in(rng, 0, b.len() - 1);
                        b[pos] ^= 1 << gen::usize_in(rng, 0, 7);
                    }
                    std::fs::write(&p, &b).map_err(|e| e.to_string())?;
                }
                5 => {
                    // validly-framed index whose entry lens lie — including
                    // absurd lengths a naive scrubber would try to allocate
                    let p = dir.join("index.cuszi");
                    let raw = std::fs::read(&p).map_err(|e| e.to_string())?;
                    if let Ok(mut index) = StoreIndex::from_bytes(&raw) {
                        if !index.entries.is_empty() {
                            let k = rng.below(index.entries.len() as u64) as usize;
                            let bump = *gen::pick(rng, &[100u64, 1 << 20, 1 << 40]);
                            index.entries[k].len =
                                index.entries[k].len.saturating_add(bump);
                            std::fs::write(&p, index.to_bytes())
                                .map_err(|e| e.to_string())?;
                        }
                    }
                }
                6 => {
                    // stale machinery: dead-writer lock debris + index tmp
                    std::fs::write(dir.join("index.cuszi.tmp"), b"half an index")
                        .map_err(|e| e.to_string())?;
                    std::fs::write(dir.join(".writer.lock.4000000000.tmp"), b"4000000000")
                        .map_err(|e| e.to_string())?;
                }
                _ => {
                    // stomp the shard magic
                    let p = shard_path(&dir, rng.below(2));
                    let Ok(mut b) = std::fs::read(&p) else { continue };
                    for (i, v) in b.iter_mut().take(8).enumerate() {
                        *v = 0xA5 ^ i as u8;
                    }
                    std::fs::write(&p, &b).map_err(|e| e.to_string())?;
                }
            }
        }

        // a scan must always answer — classify or report fatal, never
        // panic, never balloon (huge claimed lens are bounds-checked)
        let first = scan(&dir).map_err(|e| format!("scan errored: {e:#}"))?;

        // repair+quarantine converges, unless the index itself is beyond
        // parsing (fatal by contract: restore from a replica)
        let repaired = fsck(&dir, &FsckOptions { repair: true, quarantine: true })
            .map_err(|e| format!("repair errored: {e:#}"))?;
        if repaired.fatal.is_some() {
            if first.fatal.is_none() {
                return Err(format!(
                    "repair went fatal where scan did not:\nscan:\n{}\nrepair:\n{}",
                    first.render(),
                    repaired.render()
                ));
            }
            return Ok(());
        }
        if repaired.exit_code() != 0 {
            return Err(format!("repair left findings:\n{}", repaired.render()));
        }
        let second = scan(&dir).map_err(|e| format!("rescan errored: {e:#}"))?;
        if !second.clean() {
            return Err(format!("repair did not converge:\n{}", second.render()));
        }
        // and the healed bundle is a real store again
        let s = Store::open(&dir).map_err(|e| format!("repaired store won't open: {e:#}"))?;
        s.verify().map_err(|e| format!("repaired store fails verify: {e:#}"))?;
        Ok(())
    });
    std::fs::remove_dir_all(&dir).unwrap();
}
