//! Error-bound conformance suite — the paper's core correctness claim
//! (§2: |d − d°| ≤ eb for every value), swept systematically instead of
//! spot-checked: every datagen profile × dimensionality × error-bound
//! mode × codec (including per-chunk auto) must decode within the bound
//! through serialized archive bytes, with finite quality metrics.
//!
//! Fields are synthesized at reduced dims (same generators as the full
//! datasets, smaller axes) so the whole matrix stays test-suite fast.

use cusz::codec::{CodecGranularity, CodecSpec, EncoderChoice};
use cusz::config::{BackendKind, CuszConfig, ErrorBound, LosslessStage};
use cusz::container::Archive;
use cusz::coordinator::Coordinator;
use cusz::datagen::{profiles, Dataset};
use cusz::field::Field;
use cusz::metrics;
use cusz::util::prng::Rng;

/// Reduced-size stand-ins: one representative field per dataset profile,
/// with dims shaped like the original (Table 2) but test-sized.
fn profile_fields() -> Vec<Field> {
    let cases: Vec<(Dataset, &str, Vec<usize>)> = vec![
        (Dataset::Hacc, "x", vec![40_000]),
        (Dataset::Hacc, "vx", vec![40_000]),
        (Dataset::CesmAtm, "CLDHGH", vec![90, 180]),
        (Dataset::CesmAtm, "PS", vec![90, 180]),
        (Dataset::Hurricane, "CLOUDf48", vec![13, 50, 50]),
        (Dataset::Nyx, "baryon_density", vec![32, 32, 32]),
        (Dataset::Qmcpack, "einspline", vec![9, 8, 16, 16]),
    ];
    cases
        .into_iter()
        .map(|(ds, fname, dims)| {
            let mut rng = Rng::new(7 ^ dims.iter().sum::<usize>() as u64);
            let data = profiles::synthesize(ds, fname, &dims, &mut rng);
            Field::new(format!("{}/{fname}", ds.name()), dims, data).unwrap()
        })
        .collect()
}

fn codecs() -> Vec<CodecSpec> {
    let spec = |encoder, granularity| CodecSpec {
        encoder,
        lossless: LosslessStage::None,
        granularity,
    };
    vec![
        spec(EncoderChoice::Huffman, CodecGranularity::Field),
        spec(EncoderChoice::Fle, CodecGranularity::Field),
        spec(EncoderChoice::Rle, CodecGranularity::Field),
        spec(EncoderChoice::Auto, CodecGranularity::Field),
        spec(EncoderChoice::Auto, CodecGranularity::Chunk),
        // one lossless-tail leg to confirm the wrapper changes nothing
        CodecSpec {
            encoder: EncoderChoice::Auto,
            lossless: LosslessStage::Zstd,
            granularity: CodecGranularity::Chunk,
        },
    ]
}

/// Run one (field, eb mode, codec) cell and assert the conformance
/// contract: bound respected, PSNR well-defined, metadata consistent.
fn check_cell(field: &Field, eb: ErrorBound, codec: CodecSpec) {
    let coord = Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb,
        codec,
        ..Default::default()
    })
    .unwrap();
    let (archive, stats) = coord.compress_with_stats(field).unwrap();
    // decode through serialized bytes, like every real consumer
    let restored = Archive::from_bytes(&archive.to_bytes()).unwrap();
    let out = coord.decompress(&restored).unwrap();
    assert_eq!(out.dims, field.dims);

    let abs_eb = archive.header.abs_eb;
    let label = format!("{} {eb:?} {codec:?}", field.name);
    // max abs error <= resolved absolute bound
    if let Some(i) = metrics::verify_error_bound(&field.data, &out.data, abs_eb) {
        panic!(
            "{label}: bound violated at {i}: {} vs {} (abs_eb {abs_eb})",
            field.data[i], out.data[i]
        );
    }
    // valrel mode: the resolved bound must match eb × value range
    if let ErrorBound::ValRel(rel) = eb {
        let (lo, hi) = field.value_range();
        let expect = (rel * (hi - lo) as f64) as f32;
        assert!(
            (abs_eb - expect).abs() <= expect * 1e-5 + f32::EPSILON,
            "{label}: abs_eb {abs_eb} != {expect}"
        );
    }
    // quality metrics are well-defined (PSNR is finite unless lossless)
    let psnr = metrics::psnr(&field.data, &out.data);
    let maxerr = metrics::max_abs_error(&field.data, &out.data);
    assert!(
        psnr.is_finite() || maxerr == 0.0,
        "{label}: PSNR {psnr} with max err {maxerr}"
    );
    // max abs error respects the bound up to the documented f32 scaling
    // slack (DESIGN.md §3, mirrored from metrics::verify_error_bound)
    let max_abs = field.data.iter().fold(0f32, |a, &b| a.max(b.abs()));
    let tol = abs_eb as f64 * (1.0 + 1e-6) + 4.0 * f32::EPSILON as f64 * max_abs as f64;
    assert!(maxerr <= tol, "{label}: max err {maxerr} > tol {tol}");
    // stats agree with the archive
    assert_eq!(stats.encoder, archive.header.encoder, "{label}");
    assert_eq!(
        stats.chunk_counts.iter().sum::<usize>(),
        archive.stream.chunks.len(),
        "{label}"
    );
    if codec.granularity == CodecGranularity::Chunk && codec.encoder == EncoderChoice::Auto {
        assert_eq!(archive.chunk_tags.len(), archive.stream.chunks.len(), "{label}");
    } else {
        assert!(archive.chunk_tags.is_empty(), "{label}");
    }
}

#[test]
fn every_profile_dims_ebmode_codec_cell_conforms() {
    let fields = profile_fields();
    for field in &fields {
        for eb in [ErrorBound::Abs(1e-2), ErrorBound::ValRel(1e-3)] {
            for codec in codecs() {
                check_cell(field, eb, codec);
            }
        }
    }
}

#[test]
fn tight_bounds_conform_on_the_roughest_profile() {
    // tight bounds maximize outlier-marker density — the regime that used
    // to bias auto-selection (see codec::cost) and stresses the RLE
    // marker escape
    let mut rng = Rng::new(41);
    let data = profiles::synthesize(Dataset::Hacc, "vx", &[30_000], &mut rng);
    let field = Field::new("HACC/vx-tight", vec![30_000], data).unwrap();
    for codec in codecs() {
        check_cell(&field, ErrorBound::ValRel(1e-5), codec);
    }
}

#[test]
fn mixed_smoothness_field_conforms_and_uses_multiple_backends() {
    // one field stitched from three regimes: the per-chunk auto target.
    // 2D so slab gather order interleaves, plus enough length per regime
    // that chunks stay regime-pure in the slab-major stream.
    let mut rng = Rng::new(11);
    let n = 96 * 96;
    let mut data = Vec::with_capacity(n);
    let mut acc = 0.0f32;
    for i in 0..n {
        match (i / 2304) % 3 {
            0 => {
                acc += rng.normal() * 0.01;
                data.push(acc);
            }
            1 => data.push(rng.normal() * 5.0),
            _ => data.push(0.0),
        }
    }
    let field = Field::new("mixed", vec![96, 96], data).unwrap();
    let codec = CodecSpec {
        encoder: EncoderChoice::Auto,
        lossless: LosslessStage::None,
        granularity: CodecGranularity::Chunk,
    };
    check_cell(&field, ErrorBound::Abs(5e-3), codec);
    let coord = Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(5e-3),
        codec,
        ..Default::default()
    })
    .unwrap();
    let (archive, stats) = coord.compress_with_stats(&field).unwrap();
    let used = stats.chunk_counts.iter().filter(|&&c| c > 0).count();
    assert!(
        used >= 2,
        "mixed-regime field should split across backends: {:?}",
        stats.chunk_counts
    );
    assert_eq!(archive.header.granularity, CodecGranularity::Chunk);
}
