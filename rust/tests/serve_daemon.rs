//! Serving test battery for `cusz serve --daemon` (L3): boot the real
//! TCP front end on an ephemeral port and prove the service contracts —
//! concurrent mixed put/get traffic round-trips exactly, overload sheds
//! with `BUSY` without dropping accepted jobs, graceful drain loses
//! nothing a client was acked for, and hostile/slow clients are bounded
//! by the read timeout without wedging honest ones.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::container::Archive;
use cusz::coordinator::Coordinator;
use cusz::field::Field;
use cusz::metrics;
use cusz::serve::wire::{Client, GetOutcome, PutOutcome};
use cusz::serve::{Daemon, DaemonConfig, DaemonHandle};
use cusz::store::Store;
use cusz::testkit::fields::{make, Regime};
use cusz::testkit::tmp_dir;

const EB: f64 = 1e-2;
const TIMEOUT: Duration = Duration::from_secs(20);

fn coordinator() -> Arc<Coordinator> {
    Arc::new(
        Coordinator::new(CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::Abs(EB),
            threads: 1, // job-level parallelism comes from the daemon pool
            ..Default::default()
        })
        .unwrap(),
    )
}

fn spawn_daemon(tag: &str, cfg: DaemonConfig) -> (DaemonHandle, std::path::PathBuf) {
    let dir = tmp_dir(tag);
    let store = Store::create(&dir, 2).unwrap();
    let handle = Daemon::spawn(coordinator(), store, "127.0.0.1:0", cfg).unwrap();
    (handle, dir)
}

fn connect(handle: &DaemonHandle) -> Client {
    Client::connect(&handle.addr().to_string(), TIMEOUT, TIMEOUT).unwrap()
}

fn sample_field(name: &str, i: usize) -> Field {
    Field::new(
        name.to_string(),
        vec![48, 48],
        make(Regime::ALL[i % Regime::ALL.len()], 48 * 48, i as u64),
    )
    .unwrap()
}

/// PUT with bounded BUSY retries (the polite-client loop).
fn put_retry(client: &mut Client, field: &Field) -> PutOutcome {
    for _ in 0..200 {
        match client.put(field).unwrap() {
            PutOutcome::Busy => std::thread::sleep(Duration::from_millis(5)),
            other => return other,
        }
    }
    PutOutcome::Busy
}

fn get_retry(client: &mut Client, name: &str) -> GetOutcome {
    for _ in 0..200 {
        match client.get(name).unwrap() {
            GetOutcome::Busy => std::thread::sleep(Duration::from_millis(5)),
            other => return other,
        }
    }
    GetOutcome::Busy
}

#[test]
fn concurrent_mixed_workload_roundtrips_byte_identical() {
    let (handle, dir) = spawn_daemon(
        "daemon-mixed",
        DaemonConfig { workers: 2, queue_depth: 8, ..Default::default() },
    );
    let addr = handle.addr().to_string();

    // local single-threaded reference: compression is lossy but
    // deterministic, so the daemon's GET payload must be bit-identical
    // to compress->decompress run locally with the same config
    let reference = coordinator();

    const CLIENTS: usize = 8;
    const FIELDS_PER_CLIENT: usize = 3;
    let failures: Arc<Mutex<Vec<String>>> = Arc::default();
    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let addr = addr.clone();
            let reference = Arc::clone(&reference);
            let failures = Arc::clone(&failures);
            scope.spawn(move || {
                let run = || -> anyhow::Result<()> {
                    let mut client = Client::connect(&addr, TIMEOUT, TIMEOUT)?;
                    let fields: Vec<Field> = (0..FIELDS_PER_CLIENT)
                        .map(|j| sample_field(&format!("c{c}-f{j}"), c * FIELDS_PER_CLIENT + j))
                        .collect();
                    for f in &fields {
                        match put_retry(&mut client, f) {
                            PutOutcome::Stored { compressed_bytes, original_bytes } => {
                                anyhow::ensure!(compressed_bytes > 0);
                                anyhow::ensure!(original_bytes as usize == f.size_bytes());
                            }
                            other => anyhow::bail!("put {}: {:?}", f.name, other),
                        }
                    }
                    for f in &fields {
                        let restored = match get_retry(&mut client, &f.name) {
                            GetOutcome::Field(r) => r,
                            other => anyhow::bail!("get {}: {:?}", f.name, other),
                        };
                        anyhow::ensure!(restored.dims == f.dims, "{} dims", f.name);
                        // within the error bound of the original...
                        anyhow::ensure!(
                            metrics::verify_error_bound(&f.data, &restored.data, EB).is_none(),
                            "{} violates eb",
                            f.name
                        );
                        // ...and bit-identical to the local reference
                        let compressed = reference.compress_encoded(f)?;
                        let archive = Archive::from_bytes(&compressed.bytes)?;
                        let (expect, _) = reference.decompress_with_threads(&archive, 1)?;
                        anyhow::ensure!(
                            restored.data == expect.data,
                            "{} not byte-identical to reference",
                            f.name
                        );
                        // repeated GETs are stable
                        match get_retry(&mut client, &f.name) {
                            GetOutcome::Field(again) => {
                                anyhow::ensure!(again.data == restored.data, "{} unstable", f.name)
                            }
                            other => anyhow::bail!("re-get {}: {:?}", f.name, other),
                        }
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    failures.lock().unwrap().push(format!("client {c}: {e:#}"));
                }
            });
        }
    });
    assert!(failures.lock().unwrap().is_empty(), "{:?}", failures.lock().unwrap());

    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.put.jobs, CLIENTS * FIELDS_PER_CLIENT);
    assert_eq!(stats.put.failed, 0);
    assert_eq!(stats.gets, 2 * CLIENTS * FIELDS_PER_CLIENT);
    assert_eq!(stats.gets_failed, 0);
    assert!(stats.connections >= CLIENTS);
    assert!(stats.workers >= 1);
    assert!(stats.put.latency_percentiles().is_some());
    assert!(stats.get_latency_percentiles().is_some());
    assert!(!stats.report().is_empty());

    // everything acked is durable in the bundle on disk
    let store = Store::open(&dir).unwrap();
    assert_eq!(store.len(), CLIENTS * FIELDS_PER_CLIENT);
    store.verify().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn overload_sheds_busy_without_dropping_accepted_jobs() {
    // one slow worker + depth-1 queue: a burst of simultaneous PUTs must
    // split into explicit BUSY sheds and fully-served jobs, nothing else
    let (handle, dir) = spawn_daemon(
        "daemon-overload",
        DaemonConfig {
            workers: 1,
            queue_depth: 1,
            fault_put_delay: Some(Duration::from_millis(150)),
            ..Default::default()
        },
    );
    const BURST: usize = 6;
    let barrier = Arc::new(Barrier::new(BURST));
    let stored: Arc<Mutex<Vec<String>>> = Arc::default();
    let busy = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|scope| {
        for i in 0..BURST {
            let handle = &handle;
            let barrier = Arc::clone(&barrier);
            let stored = Arc::clone(&stored);
            let busy = Arc::clone(&busy);
            scope.spawn(move || {
                let mut client = connect(handle);
                let field = sample_field(&format!("burst-{i}"), i);
                barrier.wait();
                match client.put(&field).unwrap() {
                    PutOutcome::Stored { .. } => stored.lock().unwrap().push(field.name),
                    PutOutcome::Busy => {
                        busy.fetch_add(1, Ordering::SeqCst);
                    }
                    other => panic!("unexpected outcome: {other:?}"),
                }
            });
        }
    });
    let stored = Arc::try_unwrap(stored).unwrap().into_inner().unwrap();
    let busy = busy.load(Ordering::SeqCst);
    assert_eq!(stored.len() + busy, BURST);
    assert!(busy >= 1, "burst of {BURST} against a 1-deep queue must shed");
    assert!(!stored.is_empty(), "at least one job must be served");

    let stats = handle.shutdown().unwrap();
    assert!(stats.shed >= busy);
    assert_eq!(stats.put.jobs, stored.len());
    assert_eq!(stats.put.failed, 0);

    // exactly the acked names are in the store: accepted jobs were never
    // dropped, shed jobs never half-landed
    let store = Store::open(&dir).unwrap();
    let mut names: Vec<String> = store.list().iter().map(|e| e.name.clone()).collect();
    let mut acked = stored.clone();
    names.sort();
    acked.sort();
    assert_eq!(names, acked);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn graceful_drain_loses_nothing_and_closes_listener() {
    let (handle, dir) = spawn_daemon(
        "daemon-drain",
        DaemonConfig {
            workers: 2,
            queue_depth: 8,
            fault_put_delay: Some(Duration::from_millis(40)),
            ..Default::default()
        },
    );
    let acked: Arc<Mutex<Vec<String>>> = Arc::default();
    std::thread::scope(|scope| {
        for c in 0..4 {
            let handle = &handle;
            let acked = Arc::clone(&acked);
            scope.spawn(move || {
                let mut client = connect(handle);
                'fields: for j in 0..2 {
                    let field = sample_field(&format!("drain-{c}-{j}"), c * 2 + j);
                    loop {
                        match client.put(&field) {
                            Ok(PutOutcome::Stored { .. }) => {
                                acked.lock().unwrap().push(field.name.clone());
                                break;
                            }
                            Ok(PutOutcome::Busy) => {
                                std::thread::sleep(Duration::from_millis(5));
                            }
                            // drain raced ahead of this request (explicit
                            // refusal or connection closed): acceptable, as
                            // long as nothing *acked* is lost
                            Ok(PutOutcome::ShuttingDown) | Err(_) => break 'fields,
                            Ok(PutOutcome::Failed(e)) => panic!("put failed: {e}"),
                        }
                    }
                }
            });
        }
        // trigger the drain while puts are in flight (each takes >=40ms)
        std::thread::sleep(Duration::from_millis(60));
        handle.trigger_drain();
    });
    let addr = handle.addr();
    let stats = handle.wait().unwrap();
    let acked = Arc::try_unwrap(acked).unwrap().into_inner().unwrap();
    assert!(!acked.is_empty(), "drain fired before any put completed");
    assert_eq!(stats.put.jobs, acked.len());

    // listener is closed after the drain
    assert!(
        std::net::TcpStream::connect_timeout(&addr, Duration::from_millis(500)).is_err(),
        "post-drain connect should be refused"
    );

    // every acked name survived into the on-disk bundle
    let store = Store::open(&dir).unwrap();
    for name in &acked {
        assert!(store.contains(name), "acked '{name}' lost by drain");
    }
    store.verify().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn slow_loris_is_bounded_and_does_not_wedge_honest_clients() {
    let (handle, dir) = spawn_daemon(
        "daemon-loris",
        DaemonConfig {
            workers: 1,
            read_timeout: Duration::from_millis(300),
            ..Default::default()
        },
    );
    // hostile client: write half a header, then stall
    let mut loris = std::net::TcpStream::connect(handle.addr()).unwrap();
    {
        use std::io::Write;
        loris.write_all(&[b'c', b'Z', 1, 1]).unwrap();
        loris.flush().unwrap();
    }
    // honest client is served while the loris stalls
    let mut client = connect(&handle);
    client.ping().unwrap();
    let field = sample_field("honest", 0);
    assert!(matches!(put_retry(&mut client, &field), PutOutcome::Stored { .. }));

    // the loris connection is closed within ~read_timeout, not held open
    loris.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 64];
    let t0 = std::time::Instant::now();
    loop {
        use std::io::Read;
        match loris.read(&mut buf) {
            Ok(0) => break,          // server closed
            Ok(_) => continue,       // (a BadRequest response is fine too)
            Err(_) => break,         // reset
        }
    }
    assert!(t0.elapsed() < Duration::from_secs(5), "loris held past the timeout");

    // garbage framing gets an explicit BadRequest then close, never a hang
    {
        use std::io::{Read, Write};
        let mut garbage = std::net::TcpStream::connect(handle.addr()).unwrap();
        garbage.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        garbage.write_all(b"XXXXXXXXXXXX").unwrap();
        let mut resp = Vec::new();
        let _ = garbage.read_to_end(&mut resp);
        assert!(resp.len() >= 4, "expected a BadRequest response, got {resp:?}");
        assert_eq!(&resp[0..2], b"cZ");
        assert_eq!(resp[3], 3, "status byte should be BAD_REQUEST");
    }

    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.put.jobs, 1);
    assert!(stats.bad_requests >= 1, "garbage frame must be counted");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn memory_budget_sheds_by_bytes_while_small_requests_pass() {
    // 4 MB budget, two workers: a ~1 MB-payload PUT (admission estimate
    // ~3x its body) fits alone; a second concurrent one would overshoot
    // the budget and must shed BUSY at the header — before its body is
    // buffered — while a small PUT still rides in the leftover headroom.
    let (handle, dir) = spawn_daemon(
        "daemon-membudget",
        DaemonConfig {
            workers: 2,
            queue_depth: 8,
            mem_budget: Some(4 << 20),
            fault_put_delay: Some(Duration::from_millis(600)),
            ..Default::default()
        },
    );
    let shed_before = cusz::obs::global().counter_value(cusz::obs::keys::SERVE_MEM_SHED);
    let big = |i: usize| {
        Field::new(
            format!("big-{i}"),
            vec![512, 512],
            make(Regime::ALL[i % Regime::ALL.len()], 512 * 512, i as u64),
        )
        .unwrap()
    };
    let (big0, big1) = (big(0), big(1));

    let busy = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|scope| {
        let handle = &handle;
        let first = scope.spawn(move || {
            let mut client = connect(handle);
            assert!(matches!(client.put(&big0).unwrap(), PutOutcome::Stored { .. }));
        });
        // let the first PUT take its reservation and park in the worker
        // (the fault delay holds it there for 600ms)
        std::thread::sleep(Duration::from_millis(120));
        let mut client = connect(handle);
        match client.put(&big1).unwrap() {
            PutOutcome::Busy => {
                busy.fetch_add(1, Ordering::SeqCst);
            }
            other => panic!("second big PUT should shed by bytes, got {other:?}"),
        }
        // the shed drained the frame: the same connection keeps working,
        // and a small PUT is admitted inside the remaining headroom
        let small = sample_field("small-0", 0);
        assert!(matches!(client.put(&small).unwrap(), PutOutcome::Stored { .. }));
        first.join().unwrap();
    });

    let stats = handle.shutdown().unwrap();
    assert_eq!(busy.load(Ordering::SeqCst), 1);
    assert!(stats.shed >= 1);
    assert_eq!(stats.put.jobs, 2, "big-0 + small-0; the shed PUT never became a job");
    assert_eq!(stats.put.failed, 0);

    // governor telemetry reached the global registry (shared across
    // tests in this process, so compare against the starting point)
    let reg = cusz::obs::global();
    assert!(reg.counter_value(cusz::obs::keys::SERVE_MEM_SHED) > shed_before);
    assert!(reg.counter_value(cusz::obs::keys::SERVE_MEM_RESERVED) > 0);
    assert!(reg.counter_value(cusz::obs::keys::SERVE_MEM_PEAK) > 0);

    // accepted work landed durably; the shed PUT never half-landed
    let store = Store::open(&dir).unwrap();
    assert!(store.contains("big-0"));
    assert!(store.contains("small-0"));
    assert!(!store.contains("big-1"));
    store.verify().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tiny_budget_never_deadlocks_serial_progress() {
    // a budget smaller than any request degrades to serial admission
    // (one idle grant at a time), never to refusing everything forever
    let (handle, dir) = spawn_daemon(
        "daemon-tinybudget",
        DaemonConfig { workers: 2, queue_depth: 4, mem_budget: Some(1), ..Default::default() },
    );
    let mut client = connect(&handle);
    for i in 0..4 {
        let field = sample_field(&format!("tiny-{i}"), i);
        assert!(matches!(put_retry(&mut client, &field), PutOutcome::Stored { .. }));
        assert!(matches!(get_retry(&mut client, &field.name), GetOutcome::Field(_)));
    }
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.put.jobs, 4);
    assert_eq!(stats.put.failed, 0);
    assert_eq!(stats.gets_failed, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn stats_ping_notfound_and_wire_shutdown() {
    let (handle, dir) =
        spawn_daemon("daemon-misc", DaemonConfig { workers: 1, ..Default::default() });
    let mut client = connect(&handle);
    client.ping().unwrap();

    assert!(matches!(get_retry(&mut client, "nope"), GetOutcome::NotFound));

    let field = sample_field("present", 1);
    assert!(matches!(put_retry(&mut client, &field), PutOutcome::Stored { .. }));
    assert!(matches!(get_retry(&mut client, "present"), GetOutcome::Field(_)));

    // STATS returns the live cusz-metrics/v1 snapshot with daemon keys
    let snapshot = client.stats().unwrap();
    assert!(snapshot.contains("cusz-metrics/v1"), "{snapshot}");
    assert!(snapshot.contains("serve.daemon."), "{snapshot}");

    // wire-level SHUTDOWN drains the daemon
    client.shutdown_server().unwrap();
    let stats = handle.wait().unwrap();
    assert_eq!(stats.gets_not_found, 1);
    assert_eq!(stats.put.jobs, 1);
    assert!(stats.requests >= 5);
    std::fs::remove_dir_all(&dir).unwrap();
}
