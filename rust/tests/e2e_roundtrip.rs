//! End-to-end integration: full coordinator round trips over synthetic
//! SDRBench-like fields on both backends, archive byte-stream round trips,
//! and PJRT-vs-CPU archive equivalence (both must produce the *same
//! compressed bytes* because dual-quant is bit-exact across backends).

use cusz::config::{BackendKind, CuszConfig, ErrorBound, LosslessStage};
use cusz::container::Archive;
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
use cusz::metrics;

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.tsv")
        .exists()
}

fn cfg(backend: BackendKind) -> CuszConfig {
    CuszConfig {
        backend,
        eb: ErrorBound::ValRel(1e-4),
        artifacts_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        ..Default::default()
    }
}

#[test]
fn cpu_roundtrip_every_dataset() {
    let coord = Coordinator::new(cfg(BackendKind::Cpu)).unwrap();
    for ds in Dataset::ALL {
        let fname = ds.field_names()[0];
        let field = datagen::generate(ds, fname, 42);
        let (archive, stats) = coord.compress_with_stats(&field).unwrap();
        let out = coord.decompress(&archive).unwrap();
        assert_eq!(out.dims, field.dims);
        assert_eq!(
            metrics::verify_error_bound(&field.data, &out.data, archive.header.abs_eb),
            None,
            "{}/{}",
            ds.name(),
            fname
        );
        assert!(stats.compression_ratio() > 1.0, "{}: CR {}", ds.name(), stats.compression_ratio());
    }
}

#[test]
fn pjrt_roundtrip_and_archive_equivalence() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let pjrt = Coordinator::new(cfg(BackendKind::Pjrt)).unwrap();
    let cpu = Coordinator::new(cfg(BackendKind::Cpu)).unwrap();
    for (ds, fname) in [
        (Dataset::CesmAtm, "CLDHGH"),
        (Dataset::Hurricane, "CLOUDf48"),
        (Dataset::Nyx, "baryon_density"),
    ] {
        let field = datagen::generate(ds, fname, 7);
        let (a_pjrt, _) = pjrt.compress_with_stats(&field).unwrap();
        let (a_cpu, _) = cpu.compress_with_stats(&field).unwrap();
        // bit-exact dual-quant => identical archives
        assert_eq!(a_pjrt.to_bytes(), a_cpu.to_bytes(), "{}/{}", ds.name(), fname);

        let out = pjrt.decompress(&a_pjrt).unwrap();
        assert_eq!(
            metrics::verify_error_bound(&field.data, &out.data, a_pjrt.header.abs_eb),
            None
        );
        // cross-decompression: CPU can decode a PJRT archive
        let out2 = cpu.decompress(&a_pjrt).unwrap();
        assert_eq!(out.data, out2.data);
    }
}

#[test]
fn lossless_stage_shrinks_or_preserves() {
    let field = datagen::generate(Dataset::Hurricane, "QICEf48", 3);
    for stage in [LosslessStage::Gzip, LosslessStage::Zstd] {
        let mut c = cfg(BackendKind::Cpu);
        c.codec.lossless = stage;
        let coord = Coordinator::new(c).unwrap();
        let archive = coord.compress(&field).unwrap();
        let bytes = archive.to_bytes();
        let restored = Archive::from_bytes(&bytes).unwrap();
        let out = coord.decompress(&restored).unwrap();
        assert_eq!(
            metrics::verify_error_bound(&field.data, &out.data, archive.header.abs_eb),
            None,
            "{stage:?}"
        );
    }
}

#[test]
fn file_roundtrip() {
    let field = datagen::generate(Dataset::CesmAtm, "PS", 11);
    let coord = Coordinator::new(cfg(BackendKind::Cpu)).unwrap();
    let archive = coord.compress(&field).unwrap();
    let path = std::env::temp_dir().join("cusz_e2e_test.cusza");
    std::fs::write(&path, archive.to_bytes()).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let restored = Archive::from_bytes(&bytes).unwrap();
    assert_eq!(restored.header.field_name, field.name);
    let out = coord.decompress(&restored).unwrap();
    assert_eq!(metrics::verify_error_bound(&field.data, &out.data, restored.header.abs_eb), None);
}

#[test]
fn dict_size_sweep_cpu() {
    // Table 3's dict-size knob: CPU backend accepts non-default sizes.
    let field = datagen::generate(Dataset::CesmAtm, "CLDHGH", 21);
    for dict in [256usize, 1024, 4096] {
        let mut c = cfg(BackendKind::Cpu);
        c.dict_size = dict;
        let coord = Coordinator::new(c).unwrap();
        let (archive, _) = coord.compress_with_stats(&field).unwrap();
        assert_eq!(archive.header.dict_size, dict);
        let out = coord.decompress(&archive).unwrap();
        assert_eq!(
            metrics::verify_error_bound(&field.data, &out.data, archive.header.abs_eb),
            None,
            "dict {dict}"
        );
    }
}
