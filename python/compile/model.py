"""L2: the cuSZ compute graphs, composed from the L1 Pallas kernels.

Three graphs per slab variant, AOT-lowered by aot.py and executed from the
Rust hot path via PJRT:

  compress(data f32[shape], eb f32[1]) -> (delta i32[shape],)
     DUAL-QUANT kernel.  Codes/histogram/outliers are derived at L3 in one
     fused pass over delta: on CPU-PJRT the XLA scatter-add histogram cost
     31% of the whole graph while the L3 derivation is fused for free
     (EXPERIMENTS.md §Perf iteration 5) — on a real GPU/TPU build the
     histogram graph below would be composed back in, as in the paper.

  histogram(codes i32[shape], eb-unused) -> i32[DICT_SIZE]
     The paper's §3.2.1 privatized-replica histogram kernel, exported as a
     standalone executable (exercised by tests and the breakdown bench).

  decompress(delta i32[shape], eb f32[1]) -> f32[shape]
     Blockwise inverse-Lorenzo prefix sums, then scale by 2*eb.  The Rust
     coordinator patches outlier deltas in before calling this.
"""

import jax.numpy as jnp  # noqa: F401  (kept for kernel authorship parity)

from .kernels import dual_quant as dq
from .kernels import histogram as hist
from .kernels import lorenzo_recon as recon
from .variants import DICT_SIZE, Variant


def make_compress(variant: Variant):
    def compress(data, eb):
        delta, _codes = dq.dual_quant(variant, data, eb)
        return (delta,)

    return compress


def make_histogram(variant: Variant):
    def histogram(codes, _eb):
        return (hist.histogram(variant, codes, DICT_SIZE),)

    return histogram


def make_decompress(variant: Variant):
    def decompress(delta, eb):
        return (recon.reconstruct(variant, delta, eb),)

    return decompress
