"""AOT compiler: lower every (op, variant) graph to HLO text + manifest.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (behind
the Rust `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits:  <out-dir>/<op>_<variant>.hlo.txt  and  <out-dir>/manifest.json
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .variants import DICT_SIZE, RADIUS, VARIANTS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant, op: str) -> str:
    eb_spec = jax.ShapeDtypeStruct((1,), jnp.float32)
    if op == "compress":
        fn = model.make_compress(variant)
        data_spec = jax.ShapeDtypeStruct(variant.shape, jnp.float32)
    elif op == "histogram":
        fn = model.make_histogram(variant)
        data_spec = jax.ShapeDtypeStruct(variant.shape, jnp.int32)
    elif op == "decompress":
        fn = model.make_decompress(variant)
        data_spec = jax.ShapeDtypeStruct(variant.shape, jnp.int32)
    else:
        raise ValueError(op)
    return to_hlo_text(jax.jit(fn).lower(data_spec, eb_spec))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-sep variant names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    entries = []
    for variant in VARIANTS:
        if only and variant.name not in only:
            continue
        for op in ("compress", "histogram", "decompress"):
            text = lower_variant(variant, op)
            fname = f"{op}_{variant.name}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            entries.append(
                {
                    "op": op,
                    "variant": variant.name,
                    "file": fname,
                    "shape": list(variant.shape),
                    "block": list(variant.block),
                    "strips": variant.strips,
                    "dict_size": DICT_SIZE,
                    "radius": RADIUS,
                    "sha256": hashlib.sha256(text.encode()).hexdigest(),
                    "hlo_bytes": len(text),
                }
            )
            print(f"wrote {path} ({len(text)} bytes)")

    manifest = {
        "format": 1,
        "interchange": "hlo-text",
        "dict_size": DICT_SIZE,
        "radius": RADIUS,
        "executables": entries,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(entries)} executables)")

    # Machine-readable twin for the Rust runtime (no JSON parser needed in
    # the offline-crate environment): one row per executable.
    tpath = os.path.join(args.out_dir, "manifest.tsv")
    with open(tpath, "w") as f:
        f.write("op\tvariant\tfile\tshape\tblock\tstrips\tdict_size\tradius\tsha256\n")
        for e in entries:
            f.write(
                "\t".join(
                    [
                        e["op"],
                        e["variant"],
                        e["file"],
                        ",".join(map(str, e["shape"])),
                        ",".join(map(str, e["block"])),
                        str(e["strips"]),
                        str(e["dict_size"]),
                        str(e["radius"]),
                        e["sha256"],
                    ]
                )
                + "\n"
            )
    print(f"wrote {tpath}")


if __name__ == "__main__":
    main()
