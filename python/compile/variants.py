"""Slab-variant table shared between the AOT compiler and the Rust runtime.

Each variant fixes (slab shape, Lorenzo block shape, grid strips, dict size)
at compile time; the Rust coordinator tiles fields into these slabs and
selects a variant per field dimensionality (see rust/src/runtime/artifacts.rs,
which parses the manifest.json emitted by aot.py).

Block sizes follow the paper (§3.1.1): 32 for 1D, 16x16 for 2D, 8x8x8 for
3D.  `strips` is the Pallas grid size along axis 0 (the HBM->VMEM schedule
knob): on CPU-PJRT each interpret-mode grid step pays a full dynamic
slice/update round trip, so the shipped artifacts use strips=1 (measured
2.2x faster than strips=8 — EXPERIMENTS.md §Perf); a real-TPU build would
raise it until each strip fits VMEM (DESIGN.md §8).
"""

from dataclasses import dataclass
from typing import Tuple

# Quantization-code dictionary size (number of Huffman symbols), paper
# default: 1,024 bins; code 0 is reserved as the outlier marker.
DICT_SIZE = 1024
RADIUS = DICT_SIZE // 2

# Prequantized values are clamped to +/- PREQUANT_CAP so that all integer
# arithmetic (prediction, deltas, reconstruction prefix sums) stays exact in
# i32 (see DESIGN.md section 3.5).  Points whose prequant value would exceed
# the cap are demoted to verbatim outliers by the coordinator.
PREQUANT_CAP = 1 << 23


@dataclass(frozen=True)
class Variant:
    name: str
    shape: Tuple[int, ...]       # full slab shape
    block: Tuple[int, ...]       # Lorenzo block shape (paper section 3.1.1)
    strips: int                  # grid steps along axis 0

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def strip_shape(self) -> Tuple[int, ...]:
        assert self.shape[0] % self.strips == 0
        s0 = self.shape[0] // self.strips
        assert s0 % self.block[0] == 0, "strips must align with block rows"
        return (s0,) + self.shape[1:]


VARIANTS = [
    # 1D (HACC-like particle fields)
    Variant("1d_64k", (1 << 16,), (32,), 1),
    Variant("1d_1m", (1 << 20,), (32,), 1),
    # 2D (CESM-ATM-like lat/lon fields)
    Variant("2d_256", (256, 256), (16, 16), 1),
    Variant("2d_1k", (1024, 1024), (16, 16), 1),
    # 3D (Hurricane / Nyx; 4D QMCPACK folds its trailing axes to 3D).
    # 3d_32 keeps padding bounded on thin fields (e.g. 25x125x125).
    Variant("3d_32", (32, 32, 32), (8, 8, 8), 1),
    Variant("3d_64", (64, 64, 64), (8, 8, 8), 1),
    Variant("3d_128", (128, 128, 128), (8, 8, 8), 1),
]

BY_NAME = {v.name: v for v in VARIANTS}


def block_struct(shape: Tuple[int, ...], block: Tuple[int, ...]):
    """Interleaved (n0, B0, n1, B1, ...) reshape exposing block interiors.

    Axis 2*i+1 is the interior of block axis i; shifting along it with zero
    fill realizes the paper's zero-initialized padding layer (Figure 2).
    """
    struct = []
    interior_axes = []
    for i, (s, b) in enumerate(zip(shape, block)):
        assert s % b == 0, f"shape {shape} not divisible by block {block}"
        struct += [s // b, b]
        interior_axes.append(2 * i + 1)
    return tuple(struct), tuple(interior_axes)
