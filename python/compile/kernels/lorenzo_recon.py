"""L1 Pallas kernel: parallel inverse Lorenzo reconstruction.

The paper reconstructs cascadingly (section 3.3: "each data point cannot be
decompressed until its preceding values are fully reconstructed") and lists
decompression optimization as future work.  Because the 1st-order
l-predictor has unit integer weights and blocks are zero-padded, the
cascade telescopes to a d-dimensional inclusive prefix sum of the delta
field within each block; evaluating it with one cumsum per block axis is
bit-exact w.r.t. the cascade (all arithmetic is i32) and fully parallel
(DESIGN.md section 3.2).  Intermediate partial sums are bounded by
2^ndim * PREQUANT_CAP < 2^27, so i32 never overflows.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..variants import Variant, block_struct


def _recon_kernel(eb_ref, delta_ref, out_ref, *, strip_shape, block):
    eb = eb_ref[0]
    delta = delta_ref[...]
    struct, interior = block_struct(strip_shape, block)
    acc = delta.reshape(struct)
    for axis in interior:
        acc = jnp.cumsum(acc, axis=axis)
    out_ref[...] = acc.reshape(strip_shape).astype(jnp.float32) * (2.0 * eb)


def reconstruct(variant: Variant, delta, eb):
    """delta i32[shape] (outlier-patched) -> f32[shape] decompressed values."""
    strip = variant.strip_shape
    zeros = (0,) * (variant.ndim - 1)

    kernel = functools.partial(_recon_kernel, strip_shape=strip, block=variant.block)
    return pl.pallas_call(
        kernel,
        grid=(variant.strips,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec(strip, lambda i: (i,) + zeros),
        ],
        out_specs=pl.BlockSpec(strip, lambda i: (i,) + zeros),
        out_shape=jax.ShapeDtypeStruct(variant.shape, jnp.float32),
        interpret=True,
    )(eb, delta)
