"""Pure-numpy correctness oracles for the Pallas kernels.

Two independent references:

  * dual_quant_ref / reconstruct_ref / histogram_ref: same semantics as the
    kernels, written with plain numpy (no pallas, no jax) so a bug in the
    kernel plumbing cannot hide in a shared implementation.

  * classic_sz_ref: the ORIGINAL sequential predict-quant of Algorithm 1
    (with the loop-carried RAW cascade), used to validate the paper's
    central claim that DUAL-QUANT produces an equivalent quant-code stream
    and identical reconstruction (section 3.1.2 "Eliminating RAW").
"""

import itertools

import numpy as np

PREQUANT_CAP = 1 << 23


def _shift_one_np(x, axis):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (1, 0)
    padded = np.pad(x, pad)
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, x.shape[axis])
    return padded[tuple(idx)]


def _block_view(x, block):
    """Reshape to interleaved (n0, B0, n1, B1, ...) and return interior axes."""
    struct = []
    interior = []
    for i, (s, b) in enumerate(zip(x.shape, block)):
        assert s % b == 0
        struct += [s // b, b]
        interior.append(2 * i + 1)
    return x.reshape(struct), interior


def lorenzo_predict_ref(blocked, interior):
    ndim = len(interior)
    pred = np.zeros_like(blocked)
    for mask in range(1, 1 << ndim):
        shifted = blocked
        bits = 0
        for j in range(ndim):
            if mask >> j & 1:
                shifted = _shift_one_np(shifted, interior[j])
                bits += 1
        pred = pred + (1 if bits % 2 == 1 else -1) * shifted
    return pred


def prequant_ref(data, eb):
    # np.rint rounds half-to-even, matching XLA's round-nearest-even and
    # Rust's f32::round_ties_even (bit-exact across all three paths).
    dq = np.rint(np.asarray(data, np.float32) * (np.float32(0.5) / np.float32(eb)))
    return np.clip(dq, -PREQUANT_CAP, PREQUANT_CAP).astype(np.int32)


def dual_quant_ref(data, eb, block, radius):
    """(delta i32, codes i32) with code 0 reserved for outliers."""
    data = np.asarray(data, np.float32)
    dq = prequant_ref(data, eb)
    blocked, interior = _block_view(dq, block)
    pred = lorenzo_predict_ref(blocked, interior)
    delta = (blocked - pred).reshape(data.shape)
    in_cap = (delta > -radius) & (delta < radius)
    codes = np.where(in_cap, delta + radius, 0).astype(np.int32)
    return delta.astype(np.int32), codes


def histogram_ref(codes, nbins):
    return np.bincount(codes.reshape(-1), minlength=nbins).astype(np.int32)


def reconstruct_ref(delta, eb, block):
    blocked, interior = _block_view(np.asarray(delta, np.int64), block)
    for axis in interior:
        blocked = np.cumsum(blocked, axis=axis)
    out = blocked.reshape(delta.shape)
    assert np.abs(out).max(initial=0) <= (1 << 27), "i32 overflow in recon"
    return out.astype(np.float32) * np.float32(2.0 * eb)


def patch_outliers_ref(delta, codes, radius):
    """Rust-coordinator semantics: rebuild the full delta field from the
    Huffman-coded symbols plus the (index, delta) outlier side channel."""
    rebuilt = np.where(codes != 0, codes - radius, delta)
    return rebuilt.astype(np.int32)


def classic_sz_ref(data, eb, block, radius):
    """Algorithm 1: sequential in-situ predict-quant with the RAW cascade,
    generalized to arbitrary ndim with zero-padded blocks (Figure 2
    semantics), operating in PREQUANT space like cuSZ so the two are
    directly comparable.

    Returns (codes, deltas, reconstructed) computed the slow, cascading way.
    """
    data = np.asarray(data, np.float32)
    dq = prequant_ref(data, eb).astype(np.int64)
    recon = np.zeros_like(dq)
    ndim = data.ndim
    nblocks = [s // b for s, b in zip(data.shape, block)]
    codes = np.zeros(data.shape, np.int32)
    deltas = np.zeros(data.shape, np.int64)

    for bidx in itertools.product(*[range(n) for n in nblocks]):
        base = tuple(bi * b for bi, b in zip(bidx, block))
        for off in itertools.product(*[range(b) for b in block]):
            pos = tuple(base[i] + off[i] for i in range(ndim))
            # Lorenzo prediction from already-reconstructed neighbors,
            # zero outside the block (padding layer).
            pred = 0
            for mask in range(1, 1 << ndim):
                npos = list(off)
                bits = 0
                ok = True
                for j in range(ndim):
                    if mask >> j & 1:
                        npos[j] -= 1
                        bits += 1
                        if npos[j] < 0:
                            ok = False
                if ok:
                    gpos = tuple(base[i] + npos[i] for i in range(ndim))
                    pred += (1 if bits % 2 == 1 else -1) * recon[gpos]
            delta = dq[pos] - pred
            deltas[pos] = delta
            if -radius < delta < radius:
                codes[pos] = delta + radius
            else:
                codes[pos] = 0
            # In-situ write-back: the RAW dependency cuSZ eliminates.
            recon[pos] = pred + delta  # == dq[pos] exactly (integer space)
    return codes, deltas.astype(np.int32), recon.astype(np.float32) * np.float32(2 * eb)
