# L1 Pallas kernels for cusz-rs: dual-quant, histogram, inverse Lorenzo.
from . import dual_quant, histogram, lorenzo_recon, ref  # noqa: F401
