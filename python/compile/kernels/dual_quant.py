"""L1 Pallas kernel: DUAL-QUANTIZATION (paper section 3.1.2, Algorithm 2).

One kernel performs, per slab strip held in VMEM:

  PREQUANT   d_q = clip(rint(d / (2*eb)), +/-CAP)   (f32 -> exact i32)
  PREDICT    p   = generalized Lorenzo over the zero-padded block (i32)
  POSTQUANT  delta = d_q - p; code = delta + R if |delta| in cap else 0

The prediction is branch-free and fully vectorized: the block-padding layer
of Figure 2 is realized by shifting along block-interior axes with zero
fill, so every point (outer layer included) goes through the same
l-predictor, exactly as section 3.1.1 prescribes.  All arithmetic after
PREQUANT is integer (i32), which is the paper's "no underflow" property
(section 4.2.1, difference 2 vs OpenMP-SZ).

TPU mapping (DESIGN.md section 2): each grid step stages one strip into
VMEM via BlockSpec; the stencil is whole-tile shifted subtracts (VPU
element-wise, no MXU), so the kernel is memory-bound like the paper's
V100 version.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..variants import PREQUANT_CAP, RADIUS, Variant, block_struct


def _shift_one(x, axis):
    """Shift +1 along `axis` with zero fill (the padding layer)."""
    pad = [(0, 0)] * x.ndim
    pad[axis] = (1, 0)
    padded = jnp.pad(x, pad)
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, x.shape[axis])
    return padded[tuple(idx)]


def lorenzo_predict(blocked, interior_axes):
    """Generalized 1st-order Lorenzo prediction on the blocked i32 array.

    p = sum over nonempty subsets S of block axes of (-1)^(|S|+1) shift_S(x)
    (paper section 3.1.2, binomial-coefficient form with n=1), evaluated on
    the zero-padded block so the outer layer degrades to lower-order
    Lorenzo, exactly as Figure 2 describes.
    """
    ndim = len(interior_axes)
    pred = jnp.zeros_like(blocked)
    for mask in range(1, 1 << ndim):
        shifted = blocked
        bits = 0
        for j in range(ndim):
            if mask >> j & 1:
                shifted = _shift_one(shifted, interior_axes[j])
                bits += 1
        sign = 1 if bits % 2 == 1 else -1
        pred = pred + sign * shifted
    return pred


def _dq_kernel(eb_ref, x_ref, delta_ref, codes_ref, *, strip_shape, block):
    eb = eb_ref[0]
    d = x_ref[...]
    # PREQUANT: units of 2*eb, clamped so i32 arithmetic stays exact.
    dq = jnp.clip(
        jnp.rint(d * (0.5 / eb)),
        -float(PREQUANT_CAP),
        float(PREQUANT_CAP),
    ).astype(jnp.int32)

    struct, interior = block_struct(strip_shape, block)
    blocked = dq.reshape(struct)
    pred = lorenzo_predict(blocked, interior)
    delta = (blocked - pred).reshape(strip_shape)

    in_cap = jnp.logical_and(delta > -RADIUS, delta < RADIUS)
    codes = jnp.where(in_cap, delta + RADIUS, 0).astype(jnp.int32)

    delta_ref[...] = delta
    codes_ref[...] = codes


def dual_quant(variant: Variant, data, eb):
    """Run DUAL-QUANT over one slab.

    Args:
      data: f32[variant.shape] raw values.
      eb:   f32[1] absolute error bound.
    Returns:
      (delta i32[shape], codes i32[shape]); codes==0 marks outliers whose
      exact integer delta is carried in `delta` (DESIGN.md section 3.1).
    """
    strip = variant.strip_shape
    nd = variant.ndim
    zeros = (0,) * (nd - 1)

    def strip_idx(i):
        return (i,) + zeros

    kernel = functools.partial(_dq_kernel, strip_shape=strip, block=variant.block)
    return pl.pallas_call(
        kernel,
        grid=(variant.strips,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec(strip, strip_idx),
        ],
        out_specs=[
            pl.BlockSpec(strip, strip_idx),
            pl.BlockSpec(strip, strip_idx),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(variant.shape, jnp.int32),
            jax.ShapeDtypeStruct(variant.shape, jnp.int32),
        ],
        interpret=True,
    )(eb, data)
