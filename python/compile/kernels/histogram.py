"""L1 Pallas kernel: quantization-bin histogram (paper section 3.2.1).

Mirrors the Gomez-Luna replicated-histogram algorithm: each grid step owns a
private per-strip histogram (the CUDA version's per-block shared-memory
replica) built with a scatter-add, then accumulates it into the single
output histogram that lives at a constant block index across the grid (the
CUDA version's final parallel reduction)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..variants import Variant


def _hist_kernel(codes_ref, hist_ref, *, nbins):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    codes = codes_ref[...].reshape(-1)
    # Private replica for this strip, merged into the global histogram.
    private = jnp.zeros((nbins,), jnp.int32).at[codes].add(1)
    hist_ref[...] += private


def histogram(variant: Variant, codes, nbins: int):
    """codes i32[variant.shape] -> hist i32[nbins]."""
    strip = variant.strip_shape
    zeros = (0,) * (variant.ndim - 1)

    kernel = functools.partial(_hist_kernel, nbins=nbins)
    return pl.pallas_call(
        kernel,
        grid=(variant.strips,),
        in_specs=[pl.BlockSpec(strip, lambda i: (i,) + zeros)],
        out_specs=pl.BlockSpec((nbins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins,), jnp.int32),
        interpret=True,
    )(codes)
