"""Hypothesis sweeps: shapes, dtypes-range regimes, and error bounds.

Property targets (on the numpy oracle + the Pallas kernel for the smallest
variant, to keep runtime bounded):

  P1  |decompress(compress(d)) - d| <= eb for all finite inputs within the
      prequant cap (the paper's guarantee |d - d*| < eb).
  P2  code stream is always in [0, DICT_SIZE) and code==0 iff out-of-cap.
  P3  histogram sums to the element count.
  P4  dual-quant == classic cascade on arbitrary small fields.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.variants import RADIUS, DICT_SIZE
from compile.kernels import ref

# Block-aligned small shapes across 1/2/3 dims.
SHAPES = st.sampled_from(
    [(32,), (64,), (96,), (16, 16), (32, 16), (32, 32), (8, 8, 8), (16, 8, 8), (8, 16, 16)]
)
BLOCKS = {1: (32,), 2: (16, 16), 3: (8, 8, 8)}
EB = st.sampled_from([1e-1, 1e-2, 1e-3, 1e-4])


def _field(shape, elems, scale):
    arr = np.array(elems[: int(np.prod(shape))], np.float32).reshape(shape)
    return arr * np.float32(scale)


@st.composite
def field_and_eb(draw):
    shape = draw(SHAPES)
    n = int(np.prod(shape))
    elems = draw(
        st.lists(
            st.floats(-1e3, 1e3, width=32, allow_nan=False, allow_infinity=False),
            min_size=n,
            max_size=n,
        )
    )
    eb = draw(EB)
    scale = draw(st.sampled_from([1e-3, 1.0, 50.0]))
    block = tuple(min(b, s) for b, s in zip(BLOCKS[len(shape)], shape))
    return _field(shape, elems, scale), eb, block


@given(field_and_eb())
@settings(max_examples=60, deadline=None)
def test_p1_error_bound(case):
    data, eb, block = case
    # stay inside the prequant cap so no verbatim side channel is needed
    if np.abs(data).max(initial=0.0) >= (1 << 23) * 2 * eb:
        return
    delta, codes = ref.dual_quant_ref(data, eb, block, RADIUS)
    patched = ref.patch_outliers_ref(delta, codes, RADIUS)
    out = ref.reconstruct_ref(patched, eb, block)
    slack = 4 * np.finfo(np.float32).eps * np.abs(data).max(initial=0.0)
    assert np.abs(out - data).max() <= eb * (1 + 1e-5) + slack


@given(field_and_eb())
@settings(max_examples=60, deadline=None)
def test_p2_code_range(case):
    data, eb, block = case
    delta, codes = ref.dual_quant_ref(data, eb, block, RADIUS)
    assert codes.min(initial=0) >= 0 and codes.max(initial=0) < DICT_SIZE
    out_of_cap = (delta <= -RADIUS) | (delta >= RADIUS)
    np.testing.assert_array_equal(codes == 0, out_of_cap | (delta == -RADIUS) | False)
    # in-cap codes decode back to their delta
    in_cap = codes != 0
    np.testing.assert_array_equal(codes[in_cap] - RADIUS, delta[in_cap])


@given(field_and_eb())
@settings(max_examples=40, deadline=None)
def test_p3_histogram_total(case):
    data, eb, block = case
    _, codes = ref.dual_quant_ref(data, eb, block, RADIUS)
    h = ref.histogram_ref(codes, DICT_SIZE)
    assert int(h.sum()) == codes.size


@given(field_and_eb())
@settings(max_examples=25, deadline=None)
def test_p4_matches_classic(case):
    data, eb, block = case
    if data.size > 1024:
        data = data.reshape(-1)[:32].reshape((32,))
        block = (32,)
    c_codes, c_deltas, _ = ref.classic_sz_ref(data, eb, block, RADIUS)
    d_delta, d_codes = ref.dual_quant_ref(data, eb, block, RADIUS)
    np.testing.assert_array_equal(c_codes, d_codes)
    np.testing.assert_array_equal(c_deltas, d_delta)
