"""AOT path tests: lowering produces parseable HLO text with the right
entry signature, and the manifest is consistent with the variant table."""

import json
import os
import re

import pytest

from compile import aot
from compile.variants import BY_NAME, DICT_SIZE, VARIANTS


@pytest.mark.parametrize("name", ["1d_64k", "2d_256", "3d_64"])
@pytest.mark.parametrize("op", ["compress", "decompress"])
def test_lower_produces_hlo_text(name, op):
    v = BY_NAME[name]
    text = aot.lower_variant(v, op)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # entry computation should mention the slab dimensions
    dim0 = str(v.shape[0])
    assert dim0 in text


def test_compress_signature_shapes():
    v = BY_NAME["2d_256"]
    text = aot.lower_variant(v, "compress")
    # root is a 1-tuple: delta i32[shape]
    m = re.search(r"ENTRY .*?\{(.*)\n\}", text, re.S)
    assert m is not None
    body = m.group(1)
    assert f"s32[{v.shape[0]},{v.shape[1]}]" in body


def test_histogram_signature_shapes():
    v = BY_NAME["2d_256"]
    text = aot.lower_variant(v, "histogram")
    assert f"s32[{DICT_SIZE}]" in text
    assert f"s32[{v.shape[0]},{v.shape[1]}]" in text


def test_decompress_signature_shapes():
    v = BY_NAME["1d_64k"]
    text = aot.lower_variant(v, "decompress")
    assert f"f32[{v.shape[0]}]" in text
    assert f"s32[{v.shape[0]}]" in text


def test_manifest_if_built():
    """If `make artifacts` has run, the manifest must cover every variant."""
    mpath = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        manifest = json.load(f)
    have = {(e["op"], e["variant"]) for e in manifest["executables"]}
    if len(have) < 3 * len(VARIANTS):
        pytest.skip("partial artifact build (--only)")
    for v in VARIANTS:
        assert ("compress", v.name) in have
        assert ("histogram", v.name) in have
        assert ("decompress", v.name) in have
    for e in manifest["executables"]:
        v = BY_NAME[e["variant"]]
        assert tuple(e["shape"]) == v.shape
        assert e["dict_size"] == DICT_SIZE
        path = os.path.join(os.path.dirname(mpath), e["file"])
        assert os.path.exists(path)
