"""Kernel-vs-oracle correctness: the CORE signal for the L1 layer.

Every Pallas kernel is compared element-exactly against the independent
numpy reference in compile.kernels.ref, across all slab variants and a
grid of error bounds and data regimes.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.variants import BY_NAME, DICT_SIZE, RADIUS, VARIANTS
from compile.kernels import dual_quant as dq
from compile.kernels import histogram as hist
from compile.kernels import lorenzo_recon as recon
from compile.kernels import ref

SMALL = ["1d_64k", "2d_256", "3d_64"]
EBS = [1e-2, 1e-3, 1e-4]


def gen_field(shape, kind, seed=0):
    rng = np.random.default_rng(seed)
    if kind == "smooth":
        # Smooth field: random low-order Fourier-ish sum -> high predictability.
        idx = np.indices(shape).astype(np.float32)
        f = np.zeros(shape, np.float32)
        for k in range(1, 4):
            phase = rng.uniform(0, 2 * np.pi, size=len(shape)).astype(np.float32)
            f += np.cos(
                sum(idx[d] * (0.05 * k) + phase[d] for d in range(len(shape)))
            ).astype(np.float32)
        return f
    if kind == "noisy":
        return (rng.standard_normal(shape) * 5).astype(np.float32)
    if kind == "zeros":
        f = np.zeros(shape, np.float32)
        mask = rng.random(shape) < 0.02
        f[mask] = rng.standard_normal(mask.sum()).astype(np.float32) * 10
        return f
    raise ValueError(kind)


@pytest.mark.parametrize("name", SMALL)
@pytest.mark.parametrize("eb", EBS)
@pytest.mark.parametrize("kind", ["smooth", "noisy", "zeros"])
def test_dual_quant_matches_ref(name, eb, kind):
    v = BY_NAME[name]
    data = gen_field(v.shape, kind)
    delta, codes = dq.dual_quant(v, jnp.asarray(data), jnp.asarray([eb], np.float32))
    rdelta, rcodes = ref.dual_quant_ref(data, eb, v.block, RADIUS)
    np.testing.assert_array_equal(np.asarray(delta), rdelta)
    np.testing.assert_array_equal(np.asarray(codes), rcodes)


@pytest.mark.parametrize("name", SMALL)
def test_histogram_matches_ref(name):
    v = BY_NAME[name]
    rng = np.random.default_rng(3)
    codes = rng.integers(0, DICT_SIZE, size=v.shape, dtype=np.int32)
    h = np.asarray(hist.histogram(v, jnp.asarray(codes), DICT_SIZE))
    np.testing.assert_array_equal(h, ref.histogram_ref(codes, DICT_SIZE))
    assert int(h.sum()) == v.size


@pytest.mark.parametrize("name", SMALL)
@pytest.mark.parametrize("eb", EBS)
@pytest.mark.parametrize("kind", ["smooth", "noisy", "zeros"])
def test_roundtrip_error_bound(name, eb, kind):
    """compress -> patch outliers -> decompress stays within eb everywhere."""
    v = BY_NAME[name]
    data = gen_field(v.shape, kind, seed=7)
    ebv = jnp.asarray([eb], np.float32)
    delta, codes = dq.dual_quant(v, jnp.asarray(data), ebv)
    patched = ref.patch_outliers_ref(np.asarray(delta), np.asarray(codes), RADIUS)
    out = np.asarray(recon.reconstruct(v, jnp.asarray(patched), ebv))
    rout = ref.reconstruct_ref(patched, eb, v.block)
    np.testing.assert_array_equal(out, rout)
    # Strict error bound (rint ties can touch eb exactly; allow 1 ulp).
    slack = 4 * np.finfo(np.float32).eps * np.abs(data).max()
    assert np.abs(out - data).max() <= eb * (1 + 1e-6) + slack


@pytest.mark.parametrize("name", [v.name for v in VARIANTS])
def test_all_variants_shapes(name):
    """Every AOT variant compiles and produces correctly-shaped outputs."""
    v = BY_NAME[name]
    data = gen_field(v.shape, "zeros", seed=1)
    ebv = jnp.asarray([1e-3], np.float32)
    delta, codes = dq.dual_quant(v, jnp.asarray(data), ebv)
    assert delta.shape == v.shape and delta.dtype == jnp.int32
    h = hist.histogram(v, codes, DICT_SIZE)
    assert h.shape == (DICT_SIZE,)
    out = recon.reconstruct(v, delta, ebv)
    assert out.shape == v.shape and out.dtype == jnp.float32


def test_outlier_code_zero_reserved():
    """A spike larger than radius*2eb must produce code 0 and an exact delta."""
    v = BY_NAME["1d_64k"]
    data = np.zeros(v.shape, np.float32)
    data[100] = 1000.0  # delta = 1000/(2*0.01) = 50000 >> radius
    eb = 0.01
    delta, codes = dq.dual_quant(v, jnp.asarray(data), jnp.asarray([eb], np.float32))
    delta, codes = np.asarray(delta), np.asarray(codes)
    assert codes[100] == 0
    assert delta[100] == 50000
    # neighbor inside the same block predicts from the outlier's exact
    # prequant value, so its delta is the mirror-image spike
    assert delta[101] == -50000 and codes[101] == 0
    patched = ref.patch_outliers_ref(delta, codes, RADIUS)
    out = ref.reconstruct_ref(patched, eb, v.block)
    assert abs(out[100] - 1000.0) <= eb
    assert np.abs(out - data).max() <= eb


def test_prequant_cap_clamps():
    """Values beyond the i32-exactness cap clamp instead of corrupting."""
    v = BY_NAME["1d_64k"]
    data = np.zeros(v.shape, np.float32)
    data[0] = 1e12
    eb = 1e-4
    delta, codes = dq.dual_quant(v, jnp.asarray(data), jnp.asarray([eb], np.float32))
    d = np.asarray(delta)
    assert d[0] == ref.PREQUANT_CAP  # clamped, not wrapped
    # Reconstruction of everything else is still exact.
    patched = ref.patch_outliers_ref(d, np.asarray(codes), RADIUS)
    out = ref.reconstruct_ref(patched, eb, v.block)
    assert np.abs(out[32:] - data[32:]).max() <= eb
