"""The paper's central correctness claim (section 3.1.2): DUAL-QUANT is
equivalent to the original cascading predict-quant — same reconstruction,
same error behaviour — while being dependency-free.

We validate against classic_sz_ref (Algorithm 1, sequential RAW cascade)
on small blocks where the O(n * 2^d) python loop is affordable.
"""

import numpy as np
import pytest

from compile.kernels import ref

RADIUS = 512


def rand_field(shape, scale, seed):
    rng = np.random.default_rng(seed)
    smooth = rng.standard_normal(shape).astype(np.float32)
    # integrate along each axis to induce Lorenzo-predictable smoothness
    for ax in range(len(shape)):
        smooth = np.cumsum(smooth, axis=ax, dtype=np.float32)
    return smooth * np.float32(scale / max(1.0, np.abs(smooth).max()))


CASES = [
    ((64,), (32,)),
    ((64, 32), (16, 16)),
    ((16, 16, 16), (8, 8, 8)),
]


@pytest.mark.parametrize("shape,block", CASES)
@pytest.mark.parametrize("eb", [1e-2, 1e-3])
def test_dual_quant_equals_classic_cascade(shape, block, eb):
    data = rand_field(shape, 10.0, seed=11)
    c_codes, c_deltas, c_recon = ref.classic_sz_ref(data, eb, block, RADIUS)
    d_delta, d_codes = ref.dual_quant_ref(data, eb, block, RADIUS)
    # identical code streams => identical Huffman input => identical ratio
    np.testing.assert_array_equal(c_codes, d_codes)
    np.testing.assert_array_equal(c_deltas, d_delta)
    # identical reconstruction
    patched = ref.patch_outliers_ref(d_delta, d_codes, RADIUS)
    d_recon = ref.reconstruct_ref(patched, eb, block)
    np.testing.assert_array_equal(c_recon, d_recon)
    # f32 guarantee: eb plus value-proportional rounding of the final
    # d*2eb multiply (present in any f32 SZ implementation)
    slack = 4 * np.finfo(np.float32).eps * np.abs(data).max()
    assert np.abs(d_recon - data).max() <= eb * (1 + 1e-6) + slack


@pytest.mark.parametrize("shape,block", CASES)
def test_cascade_recon_is_prefix_sum(shape, block):
    """Inverse Lorenzo == per-axis cumsum (DESIGN.md section 3.2)."""
    rng = np.random.default_rng(5)
    delta = rng.integers(-100, 100, size=shape).astype(np.int32)
    out = ref.reconstruct_ref(delta, 0.5, block)  # 2*eb == 1.0 => raw ints
    # brute force cascade
    blocked, interior = ref._block_view(delta.astype(np.int64), block)
    expect = blocked.copy()
    # cascading reconstruction: d = pred(recon) + delta, done point by point
    # via the classic loop on an all-delta field
    flat = np.zeros(shape, np.int64)
    import itertools

    nblocks = [s // b for s, b in zip(shape, block)]
    ndim = len(shape)
    for bidx in itertools.product(*[range(n) for n in nblocks]):
        base = tuple(bi * b for bi, b in zip(bidx, block))
        for off in itertools.product(*[range(b) for b in block]):
            pos = tuple(base[i] + off[i] for i in range(ndim))
            pred = 0
            for mask in range(1, 1 << ndim):
                npos = list(off)
                bits = 0
                ok = True
                for j in range(ndim):
                    if mask >> j & 1:
                        npos[j] -= 1
                        bits += 1
                        if npos[j] < 0:
                            ok = False
                if ok:
                    g = tuple(base[i] + npos[i] for i in range(ndim))
                    pred += (1 if bits % 2 == 1 else -1) * flat[g]
            flat[pos] = pred + delta[pos]
    np.testing.assert_array_equal(out, flat.astype(np.float32))
